//! `loadgen` — open-loop load generator for the HTTP front door.
//!
//! Sweeps offered load × shard count against a self-hosted server
//! (synthetic weights, ephemeral port — no artifacts needed) and emits
//! `BENCH_serve.json` with goodput and p50/p99/p999 latency per point:
//! the measured saturation curve behind EXPERIMENTS.md §Serving.
//!
//! Open-loop means request *i* is due at `t0 + i/rate` regardless of how
//! slow earlier responses were — the arrival process does not slow down
//! when the server saturates, which is what exposes the latency knee.
//!
//! ```text
//! cargo run --release --bin loadgen -- \
//!     --shards 1,2,4 --rates 50,100,200,400 --secs 2 --conns 8
//! ```
//!
//! `--addr HOST:PORT` instead drives an already-running external server
//! (one sweep; the shard list is ignored).

use anyhow::{anyhow, Context, Result};
use scnn::accel::layers::NetworkSpec;
use scnn::accel::network::QuantizedWeights;
use scnn::benchutil::{BenchResult, JsonReport};
use scnn::engine::{BackendKind, Engine, EngineConfig, PoolConfig};
use scnn::serve::{read_response, ServeConfig, Server, TenantRegistry};
use std::collections::HashMap;
use std::io::Write as _;
use std::net::TcpStream;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut m = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let Some(key) = args[i].strip_prefix("--") else {
            i += 1;
            continue;
        };
        if let Some((k, v)) = key.split_once('=') {
            m.insert(k.to_string(), v.to_string());
            i += 1;
        } else if i + 1 < args.len() && !args[i + 1].starts_with("--") {
            m.insert(key.to_string(), args[i + 1].clone());
            i += 2;
        } else {
            m.insert(key.to_string(), "true".to_string());
            i += 1;
        }
    }
    m
}

fn flag<T>(flags: &HashMap<String, String>, key: &str, default: T) -> Result<T>
where
    T: std::str::FromStr,
    T::Err: std::fmt::Display,
{
    match flags.get(key) {
        None => Ok(default),
        Some(v) => v
            .parse()
            .map_err(|e| anyhow!("flag --{key}: cannot parse value {v:?}: {e}")),
    }
}

fn parse_list(flags: &HashMap<String, String>, key: &str, default: &str) -> Result<Vec<usize>> {
    let text = flags.get(key).cloned().unwrap_or_else(|| default.to_string());
    text.split(',')
        .map(|tok| {
            tok.trim()
                .parse::<usize>()
                .map_err(|e| anyhow!("flag --{key}: cannot parse {tok:?}: {e}"))
        })
        .collect()
}

/// One request's fate, as seen by a load-gen worker.
struct Sample {
    status: u16,
    latency_us: u64,
}

/// Sends one keep-alive request, reconnecting on failure. Returns the
/// status code; any transport error surfaces as `Err`.
fn send_request(conn: &mut Option<TcpStream>, addr: &str, request: &[u8]) -> std::io::Result<u16> {
    if conn.is_none() {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        stream.set_read_timeout(Some(Duration::from_secs(10)))?;
        *conn = Some(stream);
    }
    // The unwrap-free take/put dance keeps the connection out of the
    // Option only while it can still fail.
    let mut stream = match conn.take() {
        Some(s) => s,
        None => return Err(std::io::Error::other("no connection")),
    };
    let outcome = stream.write_all(request).and_then(|()| read_response(&mut stream));
    match outcome {
        Ok((status, headers, _body)) => {
            let closing = headers.iter().any(|(k, v)| k == "connection" && v == "close");
            if !closing {
                *conn = Some(stream);
            }
            Ok(status)
        }
        Err(e) => Err(e),
    }
}

/// Drives `total` requests open-loop at `rate` req/s over `conns`
/// keep-alive connections. Returns every sample plus the i/o error count.
fn run_point(addr: &str, body: &str, rate: f64, total: usize, conns: usize) -> (Vec<Sample>, u64) {
    let request = format!(
        "POST /v1/infer HTTP/1.1\r\nHost: loadgen\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\n\r\n{}",
        body.len(),
        body
    )
    .into_bytes();
    let next = AtomicUsize::new(0);
    let t0 = Instant::now();
    let mut merged = Vec::with_capacity(total);
    let mut io_errors = 0u64;
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(conns);
        for _ in 0..conns {
            let request = &request;
            let next = &next;
            handles.push(scope.spawn(move || {
                let mut conn: Option<TcpStream> = None;
                let mut samples = Vec::new();
                let mut errors = 0u64;
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= total {
                        break;
                    }
                    // Open-loop schedule: request i is due at t0 + i/rate,
                    // no matter how the server is doing.
                    let due = Duration::from_secs_f64(i as f64 / rate);
                    let elapsed = t0.elapsed();
                    if due > elapsed {
                        std::thread::sleep(due - elapsed);
                    }
                    let t = Instant::now();
                    match send_request(&mut conn, addr, request) {
                        Ok(status) => samples.push(Sample {
                            status,
                            latency_us: t.elapsed().as_micros() as u64,
                        }),
                        Err(_) => {
                            errors += 1;
                            conn = None;
                        }
                    }
                }
                (samples, errors)
            }));
        }
        for handle in handles {
            if let Ok((samples, errors)) = handle.join() {
                merged.extend(samples);
                io_errors += errors;
            }
        }
    });
    (merged, io_errors)
}

fn percentile(sorted_us: &[u64], p: f64) -> u64 {
    if sorted_us.is_empty() {
        return 0;
    }
    let rank = (sorted_us.len() as f64 * p / 100.0).ceil() as usize;
    sorted_us[rank.clamp(1, sorted_us.len()) - 1]
}

/// Deterministic input image sized for `net` (values in [0, 1)).
fn synthetic_image(net: &NetworkSpec) -> Vec<f32> {
    let (c, h, w) = net.input;
    (0..c * h * w).map(|i| (i % 17) as f32 / 17.0).collect()
}

fn measure_sweep(
    report: &mut JsonReport,
    addr: &str,
    shards: usize,
    body: &str,
    rates: &[usize],
    secs: f64,
    conns: usize,
) {
    for &rate in rates {
        let total = ((rate as f64) * secs).round() as usize;
        let (samples, io_errors) = run_point(addr, body, rate as f64, total.max(1), conns);
        let mut ok_us: Vec<u64> =
            samples.iter().filter(|s| s.status == 200).map(|s| s.latency_us).collect();
        ok_us.sort_unstable();
        let http_200 = ok_us.len();
        let http_429 = samples.iter().filter(|s| s.status == 429).count();
        let other = samples.len() - http_200 - http_429;
        let goodput = http_200 as f64 / secs;
        let p50 = percentile(&ok_us, 50.0);
        let p99 = percentile(&ok_us, 99.0);
        let p999 = percentile(&ok_us, 99.9);
        let mean_us = if ok_us.is_empty() {
            0.0
        } else {
            ok_us.iter().sum::<u64>() as f64 / ok_us.len() as f64
        };
        let result = BenchResult {
            name: format!("serve/shards={shards}/offered={rate}"),
            median_ns: p50 as f64 * 1e3,
            mean_ns: mean_us * 1e3,
            iters: samples.len().max(1),
        };
        println!(
            "shards={shards} offered={rate}/s -> goodput {goodput:.0}/s  p50 {p50} µs  \
             p99 {p99} µs  p999 {p999} µs  (200: {http_200}, 429: {http_429}, \
             other: {other}, io: {io_errors})"
        );
        report.add(
            &result,
            &[
                ("shards", shards as f64),
                ("offered_rps", rate as f64),
                ("goodput_rps", goodput),
                ("p50_us", p50 as f64),
                ("p99_us", p99 as f64),
                ("p999_us", p999 as f64),
                ("http_200", http_200 as f64),
                ("http_429", http_429 as f64),
                ("http_other", other as f64),
                ("io_errors", io_errors as f64),
            ],
        );
    }
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let flags = parse_flags(&args);
    let net = NetworkSpec::by_name(&flag::<String>(&flags, "net", "lenet5".into())?)?;
    let kind: BackendKind = flag(&flags, "backend", BackendKind::Expectation)?;
    let shard_counts = parse_list(&flags, "shards", "1,2,4")?;
    let rates = parse_list(&flags, "rates", "50,100,200,400")?;
    let secs: f64 = flag(&flags, "secs", 2.0)?;
    let conns: usize = flag(&flags, "conns", 8)?;
    let out: String = flag(&flags, "out", "BENCH_serve.json".into())?;
    let external: String = flag(&flags, "addr", String::new())?;
    let bits: u32 = flag(&flags, "bits", 8)?;
    let k: usize = flag(&flags, "k", 32)?;

    let image = synthetic_image(&net);
    let body = format!("{{\"image\":{}}}", scnn::serve::json::render_f32s(&image));
    let mut report = JsonReport::new();

    if !external.is_empty() {
        println!("driving external server at {external}");
        measure_sweep(&mut report, &external, 0, &body, &rates, secs, conns);
    } else {
        for &shards in &shard_counts {
            let cfg = EngineConfig::new(kind, net.clone())
                .with_quantized(QuantizedWeights::synthetic(&net, bits, 7)?)
                .with_bits(bits)
                .with_k(k);
            let pool = Arc::new(
                Engine::open_pool(PoolConfig::replicated(cfg, shards))
                    .context("opening engine pool")?,
            );
            let server = Server::start(
                Arc::clone(&pool),
                TenantRegistry::open(),
                "127.0.0.1:0",
                ServeConfig::default(),
            )?;
            let addr = server.local_addr().to_string();
            println!("== {shards} shard(s) on {addr} ==");
            measure_sweep(&mut report, &addr, shards, &body, &rates, secs, conns);
            server.shutdown();
        }
    }

    let path = std::path::Path::new(&out);
    report.write(path).with_context(|| format!("writing {}", path.display()))?;
    println!("wrote {} ({} points)", path.display(), report.len());
    Ok(())
}
