// Calibration probe: print measured Table I/II quantities.
use scnn::accel::channel;
use scnn::tech::{CellLibrary, TechKind};

fn main() {
    for (name, lib) in [("FinFET", CellLibrary::finfet10()), ("RFET", CellLibrary::rfet10())] {
        let p = channel::characterize_pcc(&lib);
        let a = channel::characterize_apc(&lib);
        println!("{name} PCC8 : area {:.3} delay {:.1} energy {:.3}", p.area_um2, p.delay_ps, p.energy_per_cycle_fj);
        println!("{name} APC25: area {:.3} delay {:.1} energy {:.3}", a.area_um2, a.delay_ps, a.energy_per_cycle_fj);
    }
    for tech in [TechKind::Finfet10, TechKind::Rfet10] {
        let c = channel::characterize_channel(tech);
        println!("{tech:?} channel: area {:.0} clock {:.0} energy/cyc {:.0} leak {:.0}nW", c.area_um2, c.min_clock_ps, c.energy_per_cycle_fj, c.leakage_nw);
        println!("   tree: area {:.1} delay {:.1} e {:.2}; b2s d {:.1}; s2b d {:.1}", c.adder_tree.area_um2, c.adder_tree.delay_ps, c.adder_tree.energy_per_cycle_fj, c.b2s.delay_ps, c.s2b.delay_ps);
    }
}
