//! Calibration targets and scaling factors.
//!
//! The paper characterizes its blocks with Cadence Genus on two libraries we
//! do not have. We therefore *back-solve* per-cell parameters from the
//! paper's own block-level results (Table I) plus the stated device facts
//! (RFET on-current ≈ ¼ of FinFET, larger per-device footprint, fewer
//! transistors per logic function, much lower leakage). The derivation:
//!
//! * **FinFET 8-bit PCC** (MUX-chain, Fig. 4b) = 8 × MUX21. Table I gives
//!   2.21 µm² / 242 ps / 4.11 fJ ⇒ MUX21 ≈ 0.276 µm², ≈30 ps/stage. This is
//!   consistent with ASAP7's MUX21 (~0.13 µm²) scaled by the paper's ×2.1.
//! * **RFET 8-bit PCC** (NAND-NOR chain, Fig. 6c, Lemma 1) = 8 × NandNor +
//!   4 × Inv (inverter-insertion rule, N even ⇒ 4 inverters). Table I gives
//!   2.01 µm² / 142 ps / 2.89 fJ ⇒ NandNor ≈ 0.214 µm², ≈17.8 ps/stage.
//!   During a conversion the X inputs are *static* (held for the whole
//!   bitstream), so the Xi inverters contribute ~no switching energy — the
//!   2.89 fJ is carried by the 8 chain gates.
//! * **25-input APC** (Fig. 8a construction): our Wallace-style reduction
//!   uses 20 FA + 2 HA for the 25→5 parallel counter plus a 10-bit
//!   accumulator (4 FA + 6 HA + 10 DFF); totals 24 FA + 8 HA + 10 DFF.
//!   Table I's FinFET row (24.37 µm² / 462 ps / 40.14 fJ) pins the FinFET
//!   FA cell; the RFET row (26.15 / 593 / 35.88) pins XOR3 + MAJ3 (the
//!   compact RFET FA of Fig. 8c) with the stated slower-but-leaner trend.
//!
//! Table II and Fig. 13 are *predictions* of these calibrated cells — they
//! are validation, not calibration (see EXPERIMENTS.md).

/// Paper's ASAP7→10 nm area scaling (×2.1), §V.
pub const FINFET_AREA_SCALE: f64 = 2.1;
/// Paper's ASAP7→10 nm delay scaling (×1.3), §V.
pub const FINFET_DELAY_SCALE: f64 = 1.3;
/// Paper's ASAP7→10 nm power/energy scaling (×1.4), §V.
pub const FINFET_POWER_SCALE: f64 = 1.4;

/// One row of Table I (and the channel row of Table II).
#[derive(Debug, Clone, Copy)]
pub struct BlockTarget {
    pub area_um2: f64,
    pub delay_ps: f64,
    pub energy_fj: f64,
}

/// Table I, FinFET 10 nm, 8-bit PCC.
pub const TABLE1_FINFET_PCC8: BlockTarget =
    BlockTarget { area_um2: 2.21, delay_ps: 242.0, energy_fj: 4.11 };
/// Table I, RFET 10 nm, 8-bit PCC.
pub const TABLE1_RFET_PCC8: BlockTarget =
    BlockTarget { area_um2: 2.01, delay_ps: 142.0, energy_fj: 2.89 };
/// Table I, FinFET 10 nm, 25-input APC.
pub const TABLE1_FINFET_APC25: BlockTarget =
    BlockTarget { area_um2: 24.37, delay_ps: 462.0, energy_fj: 40.14 };
/// Table I, RFET 10 nm, 25-input APC.
pub const TABLE1_RFET_APC25: BlockTarget =
    BlockTarget { area_um2: 26.15, delay_ps: 593.0, energy_fj: 35.88 };

/// Table II, FinFET channel: 2475 µm², 0.95 ns min clock, 4.30 pJ/cycle.
pub const TABLE2_FINFET_CHANNEL: BlockTarget =
    BlockTarget { area_um2: 2475.0, delay_ps: 950.0, energy_fj: 4300.0 };
/// Table II, RFET channel: 2359 µm², 0.88 ns min clock, 3.07 pJ/cycle.
pub const TABLE2_RFET_CHANNEL: BlockTarget =
    BlockTarget { area_um2: 2359.0, delay_ps: 880.0, energy_fj: 3070.0 };

/// Relative tolerance used by the calibration regression tests for Table I
/// (cells were back-solved from these rows, so they must land tightly).
pub const CALIBRATION_RTOL: f64 = 0.05;
/// Looser tolerance for the *predicted* rows (Table II / Fig. 13): the
/// paper's channel includes glue logic we model structurally, so we accept
/// a wider band while asserting the FinFET-vs-RFET *ratios* tightly.
pub const PREDICTION_RTOL: f64 = 0.25;

/// Relative-error helper used across calibration tests and benches.
pub fn rel_err(measured: f64, target: f64) -> f64 {
    (measured - target).abs() / target.abs()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rel_err_basics() {
        assert!(rel_err(1.0, 1.0) < 1e-12);
        assert!((rel_err(1.1, 1.0) - 0.1).abs() < 1e-12);
        assert!((rel_err(0.9, 1.0) - 0.1).abs() < 1e-12);
    }

    #[test]
    fn targets_match_paper_gains() {
        // Table I reports gains: PCC area 9.1%, delay 41.6%, energy 29.7%;
        // APC area -7.2%, delay -28.4%, energy 10.6%. Check our transcription.
        let g = |f: f64, r: f64| (f - r) / f;
        assert!((g(TABLE1_FINFET_PCC8.area_um2, TABLE1_RFET_PCC8.area_um2) - 0.091).abs() < 0.005);
        assert!((g(TABLE1_FINFET_PCC8.delay_ps, TABLE1_RFET_PCC8.delay_ps) - 0.416).abs() < 0.005);
        assert!((g(TABLE1_FINFET_PCC8.energy_fj, TABLE1_RFET_PCC8.energy_fj) - 0.297).abs() < 0.005);
        assert!((g(TABLE1_FINFET_APC25.area_um2, TABLE1_RFET_APC25.area_um2) + 0.072).abs() < 0.005);
        assert!((g(TABLE1_FINFET_APC25.delay_ps, TABLE1_RFET_APC25.delay_ps) + 0.284).abs() < 0.005);
        assert!((g(TABLE1_FINFET_APC25.energy_fj, TABLE1_RFET_APC25.energy_fj) - 0.106).abs() < 0.005);
    }
}
