//! On-chip SRAM macro model.
//!
//! The paper keeps memory in FinFET for *both* systems ("for the RFET-based
//! accelerator, the memory components still use FinFETs", §V), so a single
//! FinFET-10 nm SRAM model serves both technology configurations. Table III
//! reports 10 kB of on-chip memory inside the 0.288/0.299 mm² footprint.

/// A single-port SRAM macro of a given capacity.
#[derive(Debug, Clone, Copy)]
pub struct SramMacro {
    /// Capacity in bytes.
    pub bytes: usize,
}

/// FinFET 10 nm high-density bitcell area (µm² per bit).
pub const BITCELL_AREA_UM2: f64 = 0.040;
/// Periphery (decoders, sense amps, IO) multiplier over raw bitcell array.
pub const PERIPHERY_FACTOR: f64 = 2.0;
/// Dynamic read energy per byte accessed (fJ).
pub const READ_ENERGY_FJ_PER_BYTE: f64 = 28.0;
/// Dynamic write energy per byte (fJ).
pub const WRITE_ENERGY_FJ_PER_BYTE: f64 = 34.0;
/// Leakage per byte (nW) — FinFET bitcells.
pub const LEAKAGE_NW_PER_BYTE: f64 = 0.9;

impl SramMacro {
    /// A macro holding `bytes` bytes.
    pub fn new(bytes: usize) -> Self {
        SramMacro { bytes }
    }

    /// The paper's 10 kB on-chip buffer configuration (Table III).
    pub fn paper_10kb() -> Self {
        SramMacro::new(10 * 1024)
    }

    /// Total macro area in µm² (bitcells + periphery).
    pub fn area_um2(&self) -> f64 {
        (self.bytes * 8) as f64 * BITCELL_AREA_UM2 * PERIPHERY_FACTOR
    }

    /// Energy to read `n` bytes (fJ).
    pub fn read_energy_fj(&self, n: usize) -> f64 {
        n as f64 * READ_ENERGY_FJ_PER_BYTE
    }

    /// Energy to write `n` bytes (fJ).
    pub fn write_energy_fj(&self, n: usize) -> f64 {
        n as f64 * WRITE_ENERGY_FJ_PER_BYTE
    }

    /// Static leakage power (nW).
    pub fn leakage_nw(&self) -> f64 {
        self.bytes as f64 * LEAKAGE_NW_PER_BYTE
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ten_kb_macro_fits_paper_budget() {
        let m = SramMacro::paper_10kb();
        // 10 kB must be a small fraction of the 0.288 mm² die (Table III).
        assert!(m.area_um2() < 0.05 * 0.288e6);
        assert!(m.area_um2() > 1000.0);
    }

    #[test]
    fn energy_linear_in_bytes() {
        let m = SramMacro::new(4096);
        assert_eq!(m.read_energy_fj(10), 10.0 * READ_ENERGY_FJ_PER_BYTE);
        assert!(m.write_energy_fj(10) > m.read_energy_fj(10));
    }

    #[test]
    fn leakage_scales_with_capacity() {
        assert!(SramMacro::new(2048).leakage_nw() * 2.0 - SramMacro::new(4096).leakage_nw() < 1e-9);
    }
}
