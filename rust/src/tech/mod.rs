//! Technology layer: standard-cell library models for the two technologies
//! the paper compares — a 10 nm three-independent-gate (TIG) RFET library
//! (after Gauchi et al. [38]) and a 10 nm FinFET library obtained by scaling
//! ASAP7 [39] with the paper's factors (area ×2.1, delay ×1.3, power ×1.4).
//!
//! This module replaces the role Cadence Genus + the vendor libraries play in
//! the paper: it supplies per-cell area / delay / switching-energy / leakage
//! numbers that the [`crate::sim`] estimator rolls up over
//! [`crate::netlist`] structures. Calibration of the base values against the
//! paper's Table I is documented in [`calibration`].

pub mod calibration;
pub mod finfet;
pub mod rfet;
pub mod sram;

use std::fmt;

/// The cell kinds used by the netlist builders in [`crate::sc`].
///
/// Both libraries implement the plain CMOS-style cells; the reconfigurable
/// compound cells ([`CellKind::NandNor`], [`CellKind::Xor3`],
/// [`CellKind::Maj3`]) exist only in the RFET library — asking the FinFET
/// library for them is a logic error and panics (the paper's FinFET designs
/// never use them).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum CellKind {
    /// Inverter.
    Inv,
    /// Non-inverting buffer.
    Buf,
    /// 2-input NAND.
    Nand2,
    /// 2-input NOR.
    Nor2,
    /// 2-input AND.
    And2,
    /// 2-input OR.
    Or2,
    /// 2-input XOR.
    Xor2,
    /// 2-input XNOR.
    Xnor2,
    /// 2:1 multiplexer, `inputs = [d0, d1, sel]`.
    Mux21,
    /// D flip-flop (positive edge).
    Dff,
    /// Half adder, `outputs = [sum, carry]`.
    HalfAdder,
    /// Full adder, `outputs = [sum, carry]`.
    FullAdder,
    /// RFET reconfigurable NAND/NOR gate, `inputs = [a, b, prog]`;
    /// `prog = 0` → NAND(a, b), `prog = 1` → NOR(a, b) (Fig. 6b).
    NandNor,
    /// RFET 3-input XOR (one stage of the compact full adder, Fig. 8c).
    Xor3,
    /// RFET 3-input majority gate (carry stage of the compact FA, Fig. 8c).
    Maj3,
}

impl CellKind {
    /// All kinds, for iteration in tests.
    pub const ALL: [CellKind; 15] = [
        CellKind::Inv,
        CellKind::Buf,
        CellKind::Nand2,
        CellKind::Nor2,
        CellKind::And2,
        CellKind::Or2,
        CellKind::Xor2,
        CellKind::Xnor2,
        CellKind::Mux21,
        CellKind::Dff,
        CellKind::HalfAdder,
        CellKind::FullAdder,
        CellKind::NandNor,
        CellKind::Xor3,
        CellKind::Maj3,
    ];

    /// Number of logic inputs the evaluator expects for this cell.
    pub fn num_inputs(self) -> usize {
        match self {
            CellKind::Inv | CellKind::Buf | CellKind::Dff => 1,
            CellKind::Nand2
            | CellKind::Nor2
            | CellKind::And2
            | CellKind::Or2
            | CellKind::Xor2
            | CellKind::Xnor2
            | CellKind::HalfAdder => 2,
            CellKind::Mux21
            | CellKind::FullAdder
            | CellKind::NandNor
            | CellKind::Xor3
            | CellKind::Maj3 => 3,
        }
    }

    /// Number of outputs (1 except the adders' sum/carry pairs).
    pub fn num_outputs(self) -> usize {
        match self {
            CellKind::HalfAdder | CellKind::FullAdder => 2,
            _ => 1,
        }
    }

    /// True for the RFET-only reconfigurable compound cells.
    pub fn rfet_only(self) -> bool {
        matches!(self, CellKind::NandNor | CellKind::Xor3 | CellKind::Maj3)
    }
}

impl fmt::Display for CellKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self:?}")
    }
}

/// Characterized parameters of one standard cell.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CellParams {
    /// Layout area in µm² (includes cell-internal routing).
    pub area_um2: f64,
    /// Propagation delay in ps at the library's nominal load.
    pub delay_ps: f64,
    /// Additional delay per unit of fanout beyond 1, in ps.
    pub delay_per_fanout_ps: f64,
    /// Energy per output transition in fJ (CV² at the library supply).
    pub switch_energy_fj: f64,
    /// Static leakage power in nW.
    pub leakage_nw: f64,
    /// Transistor count (reporting / sanity checks only).
    pub transistors: u32,
}

impl CellParams {
    /// Effective delay through this cell driving `fanout` loads.
    pub fn delay_at_fanout(&self, fanout: usize) -> f64 {
        self.delay_ps + self.delay_per_fanout_ps * fanout.saturating_sub(1) as f64
    }
}

/// Which of the paper's two technologies a library models.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TechKind {
    /// ASAP7 scaled to the 10 nm node (area ×2.1, delay ×1.3, power ×1.4).
    Finfet10,
    /// Open-source 10 nm TIG 4-nanowire RFET library (Gauchi et al. [38]).
    Rfet10,
}

impl fmt::Display for TechKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TechKind::Finfet10 => write!(f, "FinFET 10nm"),
            TechKind::Rfet10 => write!(f, "RFET 10nm"),
        }
    }
}

/// A characterized standard-cell library.
#[derive(Debug, Clone)]
pub struct CellLibrary {
    /// Which technology this models.
    pub kind: TechKind,
    /// Supply voltage in volts (0.7 V FinFET, 0.85 V RFET per the paper §V).
    pub supply_v: f64,
    /// Post-synthesis wiring/utilization overhead multiplier applied to the
    /// summed cell area (Genus-reported area includes routing impact).
    pub wiring_overhead: f64,
    cells: Vec<Option<CellParams>>,
}

impl CellLibrary {
    pub(crate) fn from_table(
        kind: TechKind,
        supply_v: f64,
        wiring_overhead: f64,
        table: &[(CellKind, CellParams)],
    ) -> Self {
        let mut cells = vec![None; CellKind::ALL.len()];
        for &(k, p) in table {
            cells[Self::index(k)] = Some(p);
        }
        CellLibrary { kind, supply_v, wiring_overhead, cells }
    }

    fn index(kind: CellKind) -> usize {
        CellKind::ALL.iter().position(|&k| k == kind).expect("kind in ALL")
    }

    /// Whether this library characterizes `kind`.
    pub fn has(&self, kind: CellKind) -> bool {
        self.cells[Self::index(kind)].is_some()
    }

    /// Parameters for `kind` if the library provides the cell.
    pub fn cell_if(&self, kind: CellKind) -> Option<CellParams> {
        self.cells[Self::index(kind)]
    }

    /// Parameters for `kind`.
    ///
    /// # Panics
    /// If the library does not provide the cell (e.g. RFET-only compound
    /// cells requested from the FinFET library).
    pub fn cell(&self, kind: CellKind) -> CellParams {
        self.cells[Self::index(kind)]
            .unwrap_or_else(|| panic!("{} library has no {kind} cell", self.kind))
    }

    /// The FinFET 10 nm library (ASAP7 scaled per the paper).
    pub fn finfet10() -> Self {
        finfet::library()
    }

    /// The RFET 10 nm TIG library.
    pub fn rfet10() -> Self {
        rfet::library()
    }

    /// Library for a [`TechKind`].
    pub fn for_kind(kind: TechKind) -> Self {
        match kind {
            TechKind::Finfet10 => Self::finfet10(),
            TechKind::Rfet10 => Self::rfet10(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finfet_has_all_cmos_cells() {
        let lib = CellLibrary::finfet10();
        for k in CellKind::ALL {
            if k.rfet_only() {
                assert!(!lib.has(k), "FinFET library must not expose {k}");
            } else {
                assert!(lib.has(k), "FinFET library missing {k}");
            }
        }
    }

    #[test]
    fn rfet_has_reconfigurable_cells() {
        let lib = CellLibrary::rfet10();
        for k in [CellKind::NandNor, CellKind::Xor3, CellKind::Maj3] {
            assert!(lib.has(k));
        }
    }

    #[test]
    #[should_panic(expected = "has no")]
    fn finfet_panics_on_rfet_cell() {
        CellLibrary::finfet10().cell(CellKind::NandNor);
    }

    #[test]
    fn all_params_positive() {
        for lib in [CellLibrary::finfet10(), CellLibrary::rfet10()] {
            for k in CellKind::ALL {
                if !lib.has(k) {
                    continue;
                }
                let p = lib.cell(k);
                assert!(p.area_um2 > 0.0, "{k} area");
                assert!(p.delay_ps > 0.0, "{k} delay");
                assert!(p.switch_energy_fj > 0.0, "{k} energy");
                assert!(p.transistors > 0, "{k} transistors");
            }
        }
    }

    #[test]
    fn fanout_delay_monotone() {
        let p = CellLibrary::finfet10().cell(CellKind::Nand2);
        assert!(p.delay_at_fanout(4) > p.delay_at_fanout(1));
        assert_eq!(p.delay_at_fanout(1), p.delay_ps);
    }

    #[test]
    fn supply_voltages_match_paper() {
        assert_eq!(CellLibrary::finfet10().supply_v, 0.70);
        assert_eq!(CellLibrary::rfet10().supply_v, 0.85);
    }

    #[test]
    fn rfet_fa_uses_fewer_transistors_than_finfet() {
        // Paper §III-B: CMOS FA ≈ 28 T, RFET FA = XOR3 + MAJ3 + inverters.
        let fin = CellLibrary::finfet10();
        let rf = CellLibrary::rfet10();
        let rfet_fa =
            rf.cell(CellKind::Xor3).transistors + rf.cell(CellKind::Maj3).transistors + 2 * rf.cell(CellKind::Inv).transistors;
        assert!(rfet_fa < fin.cell(CellKind::FullAdder).transistors);
    }
}
