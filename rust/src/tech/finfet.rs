//! FinFET 10 nm cell library: ASAP7 [39] values scaled by the paper's
//! factors (area ×2.1, delay ×1.3, power ×1.4), §V.
//!
//! The *base* (7 nm) values below are representative ASAP7 typical-corner
//! numbers; the MUX21 and FullAdder cells are pinned so that the 8-bit
//! MUX-chain PCC and the 25-input APC reproduce Table I (see
//! [`super::calibration`] for the derivation).

use super::calibration::{FINFET_AREA_SCALE, FINFET_DELAY_SCALE, FINFET_POWER_SCALE};
use super::{CellKind, CellLibrary, CellParams, TechKind};

/// Base (unscaled, 7 nm) cell row: (kind, area µm², delay ps, fanout-slope
/// ps, switching energy fJ, leakage nW, transistor count).
const BASE: &[(CellKind, f64, f64, f64, f64, f64, u32)] = &[
    (CellKind::Inv, 0.0292, 7.0, 1.5, 0.12, 0.60, 2),
    (CellKind::Buf, 0.0437, 11.0, 1.2, 0.18, 0.90, 4),
    (CellKind::Nand2, 0.0437, 9.0, 2.0, 0.17, 1.00, 4),
    (CellKind::Nor2, 0.0437, 10.0, 2.2, 0.17, 1.00, 4),
    (CellKind::And2, 0.0583, 13.0, 1.8, 0.22, 1.30, 6),
    (CellKind::Or2, 0.0583, 14.0, 1.8, 0.22, 1.30, 6),
    (CellKind::Xor2, 0.1020, 18.0, 2.5, 0.38, 2.00, 12),
    (CellKind::Xnor2, 0.1020, 18.0, 2.5, 0.38, 2.00, 12),
    // MUX21 pinned by Table I FinFET PCC row: 2.21 µm² / 8 stages / ×2.1.
    (CellKind::Mux21, 0.13155, 23.27, 2.5, 1.135, 2.20, 12),
    (CellKind::Dff, 0.2330, 28.0, 2.0, 0.80, 4.00, 24),
    (CellKind::HalfAdder, 0.1310, 14.9, 2.5, 0.45, 2.40, 14),
    // FullAdder pinned by Table I FinFET APC row (24 FA + 8 HA + 10 DFF).
    (CellKind::FullAdder, 0.3428, 24.9, 2.8, 0.85, 4.50, 28),
];

/// Build the scaled FinFET 10 nm library.
pub fn library() -> CellLibrary {
    let table: Vec<(CellKind, CellParams)> = BASE
        .iter()
        .map(|&(kind, area, delay, slope, energy, leak, t)| {
            (
                kind,
                CellParams {
                    area_um2: area * FINFET_AREA_SCALE,
                    delay_ps: delay * FINFET_DELAY_SCALE,
                    delay_per_fanout_ps: slope * FINFET_DELAY_SCALE,
                    switch_energy_fj: energy * FINFET_POWER_SCALE,
                    leakage_nw: leak * FINFET_POWER_SCALE,
                    transistors: t,
                },
            )
        })
        .collect();
    // Wiring overhead folded into the calibrated cell values (Genus area
    // reports at this block scale are dominated by cell area).
    CellLibrary::from_table(TechKind::Finfet10, 0.70, 1.0, &table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mux21_matches_table1_backsolve() {
        let lib = library();
        let mux = lib.cell(CellKind::Mux21);
        // 8 × MUX21 must give the Table I PCC area of 2.21 µm².
        assert!((8.0 * mux.area_um2 - 2.21).abs() < 0.01);
        // 8 stages must give ≈242 ps.
        assert!((8.0 * mux.delay_ps - 242.0).abs() < 1.0);
    }

    #[test]
    fn scaling_applied() {
        let lib = library();
        let inv = lib.cell(CellKind::Inv);
        assert!((inv.area_um2 - 0.0292 * 2.1).abs() < 1e-9);
        assert!((inv.delay_ps - 7.0 * 1.3).abs() < 1e-9);
        assert!((inv.switch_energy_fj - 0.12 * 1.4).abs() < 1e-9);
    }
}
