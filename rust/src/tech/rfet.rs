//! RFET 10 nm cell library, modeling the open-source three-independent-gate
//! (TIG) 4-nanowire RFET standard cells of Gauchi et al. [38].
//!
//! Device-level facts from the paper (§II-D, §V) shape the numbers:
//!
//! * on-state current ≈ ¼ of the FinFET ⇒ larger per-stage delay for the
//!   same function;
//! * larger per-device footprint, but far *fewer* devices per function for
//!   XOR-family and reconfigurable gates (XOR2 = 4 RFETs, NAND-NOR = 3
//!   RFETs, Fig. 6b) ⇒ compact compound cells;
//! * extremely low leakage [33];
//! * supply 0.85 V (vs 0.7 V FinFET), chosen in §V as the speed/energy
//!   balance point.
//!
//! The NandNor and XOR3/MAJ3 cells are pinned so the NAND-NOR PCC and the
//! compact-FA APC reproduce Table I (derivation in [`super::calibration`]).

use super::{CellKind, CellLibrary, CellParams, TechKind};

/// RFET 10 nm cell rows: (kind, area µm², delay ps, fanout-slope ps,
/// switching energy fJ, leakage nW, transistor count). Direct 10 nm values,
/// no scaling.
const TABLE: &[(CellKind, f64, f64, f64, f64, f64, u32)] = &[
    (CellKind::Inv, 0.0750, 11.0, 2.2, 0.150, 0.10, 2),
    (CellKind::Buf, 0.1100, 14.0, 2.0, 0.220, 0.15, 4),
    (CellKind::Nand2, 0.1100, 14.0, 2.8, 0.200, 0.18, 4),
    (CellKind::Nor2, 0.1100, 15.0, 2.9, 0.200, 0.18, 4),
    (CellKind::And2, 0.1400, 19.0, 2.6, 0.260, 0.22, 6),
    (CellKind::Or2, 0.1400, 20.0, 2.6, 0.260, 0.22, 6),
    // TIG RFETs realize XOR/XNOR in 4 devices (vs 12 in CMOS).
    (CellKind::Xor2, 0.1600, 24.0, 3.0, 0.320, 0.25, 4),
    (CellKind::Xnor2, 0.1600, 24.0, 3.0, 0.320, 0.25, 4),
    (CellKind::Mux21, 0.2600, 26.0, 3.0, 0.600, 0.35, 8),
    (CellKind::Dff, 0.6550, 35.0, 2.5, 0.750, 0.60, 18),
    (CellKind::HalfAdder, 0.2900, 26.0, 3.0, 0.500, 0.40, 10),
    // Monolithic FA characterization of the Fig. 8c composite
    // (XOR3 + MAJ3 + 2 inverters); netlists prefer the explicit composite.
    (CellKind::FullAdder, 0.7200, 40.0, 3.2, 1.700, 0.55, 14),
    // Reconfigurable 3-transistor NAND/NOR gate (Fig. 6b); pinned by the
    // Table I RFET PCC row: (2.01 − 4×Inv)/8 µm², 142/8 ps per stage.
    (CellKind::NandNor, 0.21375, 17.75, 2.6, 1.110, 0.20, 3),
    // Compact-FA stages (Fig. 8c); pinned by the Table I RFET APC row.
    (CellKind::Xor3, 0.3000, 33.5, 3.2, 1.100, 0.28, 6),
    (CellKind::Maj3, 0.2700, 30.3, 3.2, 0.880, 0.28, 6),
];

/// Build the RFET 10 nm library.
pub fn library() -> CellLibrary {
    let table: Vec<(CellKind, CellParams)> = TABLE
        .iter()
        .map(|&(kind, area, delay, slope, energy, leak, t)| {
            (
                kind,
                CellParams {
                    area_um2: area,
                    delay_ps: delay,
                    delay_per_fanout_ps: slope,
                    switch_energy_fj: energy,
                    leakage_nw: leak,
                    transistors: t,
                },
            )
        })
        .collect();
    CellLibrary::from_table(TechKind::Rfet10, 0.85, 1.0, &table)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tech::CellLibrary;

    #[test]
    fn nandnor_pcc_backsolve() {
        let lib = library();
        let nn = lib.cell(CellKind::NandNor);
        let inv = lib.cell(CellKind::Inv);
        // 8 NandNor + 4 Inv must give the Table I RFET PCC area of 2.01 µm².
        assert!((8.0 * nn.area_um2 + 4.0 * inv.area_um2 - 2.01).abs() < 0.01);
        assert!((8.0 * nn.delay_ps - 142.0).abs() < 1.0);
    }

    #[test]
    fn rfet_leakage_below_finfet() {
        let rf = library();
        let fin = CellLibrary::finfet10();
        for k in [CellKind::Inv, CellKind::Nand2, CellKind::Xor2, CellKind::Dff] {
            assert!(
                rf.cell(k).leakage_nw < fin.cell(k).leakage_nw,
                "RFET {k} leakage should be below FinFET"
            );
        }
    }

    #[test]
    fn rfet_stage_slower_than_finfet() {
        // ¼ on-current ⇒ simple gates are slower despite fewer devices.
        let rf = library();
        let fin = CellLibrary::finfet10();
        for k in [CellKind::Inv, CellKind::Nand2, CellKind::FullAdder] {
            assert!(rf.cell(k).delay_ps > fin.cell(k).delay_ps, "{k}");
        }
    }

    #[test]
    fn xor_family_compact() {
        // TIG RFET XOR2 uses 4 devices vs 12 in CMOS.
        let rf = library();
        assert_eq!(rf.cell(CellKind::Xor2).transistors, 4);
        assert_eq!(rf.cell(CellKind::NandNor).transistors, 3);
    }
}
