//! `scnn` — the L3 coordinator CLI. Every inference subcommand runs
//! through the unified `scnn::engine` API: one typed `EngineConfig`, one
//! `Session`, one `SessionMetrics` report.
//!
//! Subcommands:
//! * `serve`     — stream the synthetic test set through a session's
//!   submit/drain path (dynamic batching + backpressure), any backend;
//!   with `--listen HOST:PORT` it instead starts the HTTP/1.1 front door
//!   (`scnn::serve`): `/v1/infer`, `/v1/batch`, `/metrics`, `/healthz`,
//!   API-key tenants and quotas via `--tenants`;
//! * `simulate`  — batched in-process inference (bit-exact SC, per-bit
//!   reference, expectation/noisy/fixed-point), any k / precision;
//! * `sweep`     — Fig. 13 channel-count design-space exploration over
//!   `Engine::estimate` (the same modeled-hardware struct sessions carry);
//! * `report`    — regenerate the paper's tables (I, II, III) on stdout;
//! * `analyze`   — the `scnn::analyze` static analyzer (sc-lint): prove
//!   stream decorrelation, counter widths, IR dataflow, precision floors,
//!   and deployment quotas for a configuration (or `--all` topologies)
//!   WITHOUT running a single SC cycle; text or `--json`, CI-gateable via
//!   `--deny-warnings`, `--out` for `BENCH_analyze.json`;
//! * `calibrate` — print raw block characterization (debugging aid).
//!
//! Flags accept `--key value`, `--key=value`, and bare `--switch`;
//! unparseable values are errors, not silent defaults. (Hand-rolled
//! parsing: clap is not vendored in this offline environment — see the
//! Cargo.toml note.)

use anyhow::{anyhow, bail, Context, Result};
use scnn::accel::network::{QuantizedWeights, SparsityPolicy};
use scnn::accel::{channel, layers::NetworkSpec, metrics::argmin_by};
use scnn::data::{Artifacts, Dataset};
use scnn::engine::{
    classify, BackendKind, BatchPolicy, Engine, EngineConfig, EngineError, Placement, PoolConfig,
    Precision,
};
use scnn::faults::FaultPlan;
use scnn::serve::{ServeConfig, Server, TenantRegistry};
use scnn::tech::TechKind;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// True when a token introduces a flag (`--name`), as opposed to being a
/// flag's value. Tokens without the `--` prefix — including negative
/// numbers like `-3` — are always values (`--offset -3`, `--gain=-2.5`).
fn looks_like_flag(tok: &str) -> bool {
    tok.strip_prefix("--")
        .and_then(|rest| rest.chars().next())
        .is_some_and(|c| !c.is_ascii_digit())
}

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut m = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let Some(key) = args[i].strip_prefix("--") else {
            i += 1;
            continue;
        };
        if let Some((k, v)) = key.split_once('=') {
            // --key=value (value may be empty, negative, or contain '=').
            m.insert(k.to_string(), v.to_string());
            i += 1;
        } else if i + 1 < args.len() && !looks_like_flag(&args[i + 1]) {
            m.insert(key.to_string(), args[i + 1].clone());
            i += 2;
        } else {
            m.insert(key.to_string(), "true".to_string());
            i += 1;
        }
    }
    m
}

/// Typed flag lookup: absent → `default`; present but unparseable → error
/// (never a silent fallback), keeping the parser's own message so enum
/// flags still list their valid values.
fn flag<T>(flags: &HashMap<String, String>, key: &str, default: T) -> Result<T>
where
    T: std::str::FromStr,
    T::Err: std::fmt::Display,
{
    match flags.get(key) {
        None => Ok(default),
        Some(v) => v
            .parse()
            .map_err(|e| anyhow!("flag --{key}: cannot parse value {v:?}: {e}")),
    }
}

/// Parse a comma-separated `--k-per-layer` list (one entry per compute
/// layer, front to back).
fn parse_k_list(list: &str) -> Result<Vec<usize>> {
    list.split(',')
        .map(|tok| {
            tok.trim()
                .parse::<usize>()
                .map_err(|e| anyhow!("flag --k-per-layer: cannot parse {tok:?}: {e}"))
        })
        .collect()
}

/// Lower the precision flags onto a config: `--k-per-layer a,b,...` or
/// `--k-auto-budget B` replace the uniform `--k` (mutually exclusive).
/// Malformed policies (k = 0, non-word-multiples, wrong layer counts)
/// surface as typed errors from `EngineConfig::validate` at open.
fn apply_precision_flags(
    mut cfg: EngineConfig,
    flags: &HashMap<String, String>,
) -> Result<EngineConfig> {
    match (flags.get("k-per-layer"), flags.get("k-auto-budget")) {
        (Some(_), Some(_)) => {
            bail!("--k-per-layer and --k-auto-budget are mutually exclusive")
        }
        (Some(list), None) => cfg = cfg.with_precision(Precision::PerLayer(parse_k_list(list)?)),
        (None, Some(_)) => {
            let accuracy_budget: f64 = flag(flags, "k-auto-budget", 0.02)?;
            cfg = cfg.with_precision(Precision::Auto { accuracy_budget });
        }
        (None, None) => {}
    }
    Ok(cfg)
}

/// Parse a comma-separated `--fault-stuck` list of `wl:lane[:0|1]` sites
/// (compute layer, fan-in lane, optional stuck value — default stuck-at-1).
fn parse_stuck_list(list: &str) -> Result<Vec<(usize, usize, bool)>> {
    list.split(',')
        .map(|tok| {
            let parts: Vec<&str> = tok.trim().split(':').collect();
            let parse = |s: &str, what: &str| {
                s.parse::<usize>()
                    .map_err(|e| anyhow!("flag --fault-stuck: bad {what} in {tok:?}: {e}"))
            };
            match parts.as_slice() {
                [wl, lane] => Ok((parse(wl, "layer")?, parse(lane, "lane")?, true)),
                [wl, lane, v] => {
                    let stuck_one = match *v {
                        "0" => false,
                        "1" => true,
                        other => bail!("flag --fault-stuck: stuck value must be 0|1, got {other:?}"),
                    };
                    Ok((parse(wl, "layer")?, parse(lane, "lane")?, stuck_one))
                }
                _ => bail!("flag --fault-stuck: expected wl:lane[:0|1], got {tok:?}"),
            }
        })
        .collect()
}

/// Lower the `--fault-*` flags onto a config: a deterministic
/// [`FaultPlan`] (bit flips on the SC streams, SRAM weight upsets, SNG
/// correlation faults, stuck-at APC lanes — all seeded, so runs reproduce
/// exactly) plus an optional client-side `--deadline-us` that turns stuck
/// waits into typed `EngineError::Timeout`s.
fn apply_fault_flags(
    mut cfg: EngineConfig,
    flags: &HashMap<String, String>,
) -> Result<EngineConfig> {
    let bit_flip: f64 = flag(flags, "fault-bit-flip", 0.0)?;
    let sram: f64 = flag(flags, "fault-sram", 0.0)?;
    let corr: f64 = flag(flags, "fault-corr", 0.0)?;
    let stuck_spec: String = flag(flags, "fault-stuck", String::new())?;
    let stuck =
        if stuck_spec.is_empty() { Vec::new() } else { parse_stuck_list(&stuck_spec)? };
    if bit_flip > 0.0 || sram > 0.0 || corr > 0.0 || !stuck.is_empty() {
        let mut plan = FaultPlan::new(flag(flags, "fault-seed", 0xFA_417)?)
            .with_bit_flip_rate(bit_flip)
            .with_sram_upset_rate(sram)
            .with_sng_correlation_rate(corr);
        for (wl, lane, stuck_one) in stuck {
            plan = plan.with_stuck_lane(wl, lane, stuck_one);
        }
        cfg = cfg.with_faults(plan);
    }
    let deadline_us: u64 = flag(flags, "deadline-us", 0)?;
    if deadline_us > 0 {
        cfg = cfg.with_deadline(Duration::from_micros(deadline_us));
    }
    Ok(cfg)
}

/// Lower the sparsity flags onto a config: `--sparsity-threshold T`
/// compiles magnitude pruning (prune every weight lane whose quantized
/// bipolar value has |v| < T) into the forward plan; `--sparsity off`
/// forces the dense datapath even when a threshold flag is present — the
/// explicit A/B escape hatch. Degenerate thresholds (negative, ≥ 1,
/// non-finite) are NOT validated here: they pass through so
/// `EngineConfig::validate` can raise the typed
/// [`EngineError::InvalidSparsity`] at open, matching how malformed
/// precision policies surface.
fn apply_sparsity_flags(
    mut cfg: EngineConfig,
    flags: &HashMap<String, String>,
) -> Result<EngineConfig> {
    match flag::<String>(flags, "sparsity", String::new())?.as_str() {
        "" => {}
        "off" => return Ok(cfg.with_sparsity(SparsityPolicy::OFF)),
        other => bail!(
            "flag --sparsity: only \"off\" is accepted, got {other:?} \
             (enable pruning with --sparsity-threshold T)"
        ),
    }
    if flags.contains_key("sparsity-threshold") {
        let t: f64 = flag(flags, "sparsity-threshold", 0.0)?;
        cfg = cfg.with_sparsity(SparsityPolicy::threshold(t));
    }
    Ok(cfg)
}

fn parse_tech(s: &str) -> Result<TechKind> {
    match s {
        "rfet" => Ok(TechKind::Rfet10),
        "finfet" => Ok(TechKind::Finfet10),
        other => bail!("unknown tech {other:?} (rfet|finfet)"),
    }
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let flags = parse_flags(&args[1.min(args.len())..]);
    match cmd {
        "serve" => serve(&flags),
        "simulate" => simulate(&flags),
        "sweep" => sweep(&flags),
        "report" => report(&flags),
        "analyze" => analyze(&flags),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            bail!("unknown command {other:?}")
        }
    }
}

fn print_help() {
    println!(
        "scnn — RFET stochastic-computing NN accelerator (paper reproduction)\n\
         \n\
         USAGE: scnn <command> [--flags]  (--key value or --key=value)\n\
         \n\
         COMMANDS:\n\
           serve     --artifacts DIR --n N --backend pjrt|sc|reference|expectation\n\
                     --net lenet5|cifar_net|mnist_strided (--synthetic for\n\
                     stand-in weights) --k K --bits B --batch-max M\n\
                     --linger-ms L --queue-depth Q --threads T\n\
                     --shards S --placement rr|least|hash --pool-queue-depth P\n\
                     --k-per-layer K1,K2,... (one per compute layer) or\n\
                     --k-auto-budget B (greedy per-layer autotune)\n\
                     --fault-seed S --fault-bit-flip R --fault-sram R\n\
                     --fault-corr R (seeded fault injection, also accepted\n\
                     by simulate) --deadline-us D (typed client timeout)\n\
                     --sparsity-threshold T (prune weight lanes with\n\
                     |v| < T into the compiled plan; also accepted by\n\
                     simulate and analyze) --sparsity off (force dense)\n\
                     stream the test set through a sharded engine pool;\n\
                     --listen HOST:PORT starts the HTTP front door instead\n\
                     (POST /v1/infer, POST /v1/batch, GET /metrics,\n\
                     GET /healthz) — no dataset needed, --synthetic works:\n\
                     --tenants 'name:key[:rps[:burst]];...' (or a file path)\n\
                     --max-body BYTES (request body cap, default 1 MiB)\n\
                     --serve-for-ms MS (0 = run until killed; otherwise a\n\
                     bounded run ending in a graceful pool drain)\n\
           simulate  --mode stochastic|reference|expectation|noisy|fixed\n\
                     --net NAME --synthetic --k K --bits B --n N --threads T\n\
                     --seed S --shards S --k-per-layer L --k-auto-budget B\n\
                     --sparsity-threshold T --sparsity off\n\
                     batched in-process inference over the test set\n\
           sweep     --tech rfet|finfet --net NAME --max-channels C --k K\n\
                     --k-per-layer K1,K2,...\n\
                     Fig. 13 design space via Engine::estimate\n\
           report    --table 1|2|3                        paper tables\n\
           analyze   --net NAME or --all (every topology) --k K --bits B\n\
                     --seed S --k-per-layer L --k-auto-budget B\n\
                     --fault-seed S --fault-bit-flip R --fault-sram R\n\
                     --fault-corr R --fault-stuck wl:lane[:0|1],...\n\
                     --shards S --pool-queue-depth P\n\
                     --sparsity-threshold T --sparsity off (SC011/SC012)\n\
                     --tenants 'name:key[:rps[:burst]];...' (or a file)\n\
                     --json (machine output) --deny-warnings (CI gate)\n\
                     --out FILE (BENCH_analyze.json diagnostics+timing)\n\
                     static sc-lint over the configuration — stream\n\
                     correlation, counter widths, IR dataflow, precision\n\
                     floors, deployment quotas — no SC cycle executed;\n\
                     default k is 2^bits (the resolution floor)\n"
    );
}

/// Resolve the `--net` flag through the [`NetworkSpec::by_name`] registry.
fn net_flag(flags: &HashMap<String, String>) -> Result<NetworkSpec> {
    NetworkSpec::by_name(&flag::<String>(flags, "net", "lenet5".into())?)
}

/// `serve`/`simulate` ship only the MNIST digits test set today; reject a
/// network whose input shape cannot consume it up front, instead of
/// failing every request with a per-image length error.
fn check_dataset_fits(ds: &Dataset, net: &NetworkSpec) -> Result<()> {
    let (c, h, w) = net.input;
    let expect = c * h * w;
    if ds.images.first().is_some_and(|img| img.len() != expect) {
        bail!(
            "the digits test set has {}-pixel images but network {:?} expects {expect} \
             (input {c}x{h}x{w}); serve/simulate currently ship only the MNIST digits \
             set — choose a 28x28 topology (lenet5, mnist_strided)",
            ds.images[0].len(),
            net.name
        );
    }
    Ok(())
}

/// Build the engine config shared by `serve` and `simulate`: the network
/// comes from `--net` (default `lenet5`); weights come from the trained
/// artifact for that network, or `--synthetic` generates deterministic
/// stand-in weights (topologies without trained artifacts still exercise
/// the full datapath — accuracy is then meaningless, throughput is not).
fn net_config(
    kind: BackendKind,
    artifacts: &Artifacts,
    flags: &HashMap<String, String>,
) -> Result<EngineConfig> {
    let net = net_flag(flags)?;
    let bits: u32 = flag(flags, "bits", 8)?;
    let mut cfg = EngineConfig::new(kind, net.clone())
        .with_k(flag(flags, "k", 32)?)
        .with_bits(bits)
        .with_seed(flag(flags, "seed", 7)?)
        .with_threads(flag(flags, "threads", 0)?)
        .with_tech(parse_tech(&flag::<String>(flags, "tech", "rfet".into())?)?)
        .with_channels(flag(flags, "channels", 8)?)
        .with_batch({
            let max_batch: usize = flag(flags, "batch-max", 32)?;
            BatchPolicy {
                max_batch,
                linger: Duration::from_millis(flag(flags, "linger-ms", 2)?),
                // Default in-flight bound: two batches — latency reported
                // under open-loop load then reflects bounded queueing, not
                // the CLI's own submission burst.
                queue_depth: flag(flags, "queue-depth", 2 * max_batch.max(1))?,
            }
        });
    cfg = if kind == BackendKind::Xla {
        if !artifacts.present() {
            bail!("artifacts missing — run `make artifacts` first");
        }
        cfg.with_hlo_ladder(vec![
            (1, artifacts.hlo(&net.name, 1)),
            (8, artifacts.hlo(&net.name, 8)),
            (32, artifacts.hlo(&net.name, 32)),
        ])
    } else if flag(flags, "synthetic", false)? {
        let seed: u32 = flag(flags, "seed", 7)?;
        cfg.with_quantized(QuantizedWeights::synthetic(&net, bits, seed as u64)?)
    } else {
        let path = artifacts.weights(&net.name, "sc");
        if !path.exists() {
            bail!(
                "no trained weights at {} — run `make artifacts`, or pass \
                 --synthetic for deterministic stand-in weights",
                path.display()
            );
        }
        cfg.with_weights_file(path)
    };
    apply_sparsity_flags(apply_fault_flags(apply_precision_flags(cfg, flags)?, flags)?, flags)
}

/// Lower the CLI flags into a pool configuration: `--shards` replicas of
/// the per-session config behind a `--placement` router, with an optional
/// `--pool-queue-depth` admission bound (0 = sum of shard depths).
fn pool_config(
    kind: BackendKind,
    artifacts: &Artifacts,
    flags: &HashMap<String, String>,
) -> Result<PoolConfig> {
    let shards: usize = flag(flags, "shards", 1)?;
    let placement: Placement = flag(flags, "placement", Placement::RoundRobin)?;
    Ok(PoolConfig::replicated(net_config(kind, artifacts, flags)?, shards)
        .with_placement(placement)
        .with_queue_depth(flag(flags, "pool-queue-depth", 0)?))
}

fn serve(flags: &HashMap<String, String>) -> Result<()> {
    let listen: String = flag(flags, "listen", String::new())?;
    if !listen.is_empty() {
        return serve_network(&listen, flags);
    }
    let artifacts = Artifacts::new(flag::<String>(flags, "artifacts", "artifacts".into())?);
    let n: usize = flag(flags, "n", 200)?;
    let kind: BackendKind = flag(flags, "backend", BackendKind::Xla)?;
    if !artifacts.dataset("digits").exists() {
        bail!("artifacts missing — run `make artifacts` first");
    }
    let ds = Dataset::load(&artifacts.dataset("digits"))?;
    check_dataset_fits(&ds, &net_flag(flags)?)?;
    let n = n.min(ds.len());
    let pcfg = pool_config(kind, &artifacts, flags)?;
    let admission_depth = pcfg.effective_queue_depth();
    let pool = Engine::open_pool(pcfg).context("opening engine pool")?;

    // The streaming serve path: submit everything through the pool router,
    // drain in submission order. A full admission queue sheds with a typed
    // `Rejected` — the CLI reacts the way a well-behaved client would:
    // honor the backoff hint (capped, with deterministic jitter so
    // simultaneous clients desynchronize reproducibly), drain ONE
    // completed result (freeing one admission slot), and resubmit —
    // keeping the shard queues fed instead of collapsing the pipeline.
    // Sleeping inline is correct *here* because this loop is the one and
    // only client; the network front door (`--listen`) instead runs its
    // backoff inside each connection worker (`serve::server`), so a shed
    // tenant can never stall the accept path or unrelated connections.
    let t = Instant::now();
    let mut collected: Vec<Option<Result<Vec<f32>, EngineError>>> = Vec::with_capacity(n);
    collected.resize_with(n, || None);
    let mut backoffs = 0usize;
    for img in &ds.images[..n] {
        loop {
            match pool.submit(img.clone()) {
                Ok(_) => break,
                Err(EngineError::Rejected { retry_after_hint }) => {
                    backoffs += 1;
                    let jitter =
                        Duration::from_micros(scnn::sc::rng::mix64(backoffs as u64) % 101);
                    std::thread::sleep(
                        (retry_after_hint + jitter).min(Duration::from_millis(5)),
                    );
                    let (ticket, res) = pool.drain_one()?;
                    collected[ticket.seq() as usize] = Some(res);
                }
                Err(e) => return Err(e.into()),
            }
        }
    }
    if pool.outstanding() > 0 {
        for (ticket, res) in pool.drain()? {
            collected[ticket.seq() as usize] = Some(res);
        }
    }
    let wall = t.elapsed();
    let mut correct = 0usize;
    for (i, (slot, &label)) in collected.iter().zip(&ds.labels[..n]).enumerate() {
        let res = slot.as_ref().ok_or_else(|| anyhow!("request {i} was never drained"))?;
        let logits = res.as_ref().map_err(|e| anyhow!("request {i} failed: {e}"))?;
        correct += (classify(logits) == label as usize) as usize;
    }
    println!(
        "served {n} requests in {:.1} ms ({:.0} img/s)",
        wall.as_secs_f64() * 1e3,
        n as f64 / wall.as_secs_f64()
    );
    println!("accuracy: {:.2}% ({correct}/{n})", 100.0 * correct as f64 / n as f64);
    print!("{}", pool.metrics().summary());
    println!(
        "(open-loop submit/drain: latencies include queueing; pool admission depth \
         {admission_depth}; {backoffs} backoffs honoring retry hints)"
    );
    Ok(())
}

/// The HTTP front door: open a pool, bind `--listen`, and serve until
/// killed (or for `--serve-for-ms`, ending in a graceful drain — stop
/// accepting, let in-flight connections finish, `close()` the pool).
/// Unlike the dataset-streaming path above this needs no artifacts at
/// all when `--synthetic` is passed, so it runs in a bare checkout.
fn serve_network(listen: &str, flags: &HashMap<String, String>) -> Result<()> {
    let artifacts = Artifacts::new(flag::<String>(flags, "artifacts", "artifacts".into())?);
    let kind: BackendKind = flag(flags, "backend", BackendKind::Expectation)?;
    let pool = Arc::new(Engine::open_pool(pool_config(kind, &artifacts, flags)?)?);
    let registry = tenant_registry(flags)?;
    let tenants = registry.len();
    let serve_cfg = ServeConfig {
        max_body: flag(flags, "max-body", ServeConfig::default().max_body)?,
        ..ServeConfig::default()
    };
    let server = Server::start(Arc::clone(&pool), registry, listen, serve_cfg)?;
    println!(
        "listening on http://{} — {} shards, {} tenants ({})",
        server.local_addr(),
        pool.shards(),
        tenants,
        if tenants == 0 { "open access" } else { "API keys required" }
    );
    let serve_for_ms: u64 = flag(flags, "serve-for-ms", 0)?;
    if serve_for_ms == 0 {
        println!("serving until killed (pass --serve-for-ms to bound the run)");
        loop {
            std::thread::sleep(Duration::from_secs(3600));
        }
    }
    std::thread::sleep(Duration::from_millis(serve_for_ms));
    server.shutdown();
    print!("{}", pool.metrics().summary());
    Ok(())
}

/// Resolve the `--tenants` flag into a registry. The value is either an
/// inline `name:key[:rps[:burst]];...` spec or a path to a file holding
/// one (keeping API keys out of `ps` output). Shared by the HTTP front
/// door and the deployment lints of `analyze`.
fn tenant_registry(flags: &HashMap<String, String>) -> Result<TenantRegistry> {
    let spec: String = flag(flags, "tenants", String::new())?;
    if spec.is_empty() {
        return Ok(TenantRegistry::open());
    }
    let text = if std::path::Path::new(&spec).is_file() {
        std::fs::read_to_string(&spec).with_context(|| format!("reading {spec}"))?
    } else {
        spec
    };
    TenantRegistry::parse(&text).map_err(|e| anyhow!("--tenants: {e}"))
}

/// `scnn analyze` — run the `scnn::analyze` static analyzer over one
/// network (`--net`) or the whole topology zoo (`--all`) without
/// executing any SC cycle. Weights are deterministic synthetics (stream
/// keying, counter widths, and dataflow do not depend on trained values).
/// Exits nonzero on any `Error` diagnostic, or on any `Warning` under
/// `--deny-warnings` — the CI gate.
fn analyze(flags: &HashMap<String, String>) -> Result<()> {
    use scnn::analyze::analyze_deployment;
    let bits: u32 = flag(flags, "bits", 8)?;
    // Default k = 2^bits: the smallest stream length that resolves every
    // quantized code (shorter streams alias adjacent codes — SC004).
    let k: usize = flag(flags, "k", 1usize << bits.min(16))?;
    let seed: u32 = flag(flags, "seed", 7)?;
    let json = flag(flags, "json", false)?;
    let deny_warnings = flag(flags, "deny-warnings", false)?;
    let shards: usize = flag(flags, "shards", 1)?;
    let pool_queue_depth: usize = flag(flags, "pool-queue-depth", 0)?;
    let registry = tenant_registry(flags)?;
    let nets: Vec<NetworkSpec> = if flag(flags, "all", false)? {
        NetworkSpec::NAMES
            .iter()
            .map(|n| NetworkSpec::by_name(n))
            .collect::<Result<_>>()?
    } else {
        vec![net_flag(flags)?]
    };
    let (mut errors, mut warnings) = (0usize, 0usize);
    let mut bench = scnn::benchutil::JsonReport::new();
    let mut json_items: Vec<String> = Vec::new();
    for net in &nets {
        let t = Instant::now();
        let cfg = apply_sparsity_flags(
            apply_fault_flags(
                apply_precision_flags(
                    EngineConfig::new(BackendKind::StochasticFused, net.clone())
                        .with_k(k)
                        .with_bits(bits)
                        .with_seed(seed)
                        .with_quantized(QuantizedWeights::synthetic(net, bits, seed as u64)?),
                    flags,
                )?,
                flags,
            )?,
            flags,
        )?;
        // A policy that cannot even resolve is a typed error in its own
        // right (InvalidPrecision / InvalidSparsity) — surface it before
        // analysis instead of letting the lints silently skip it.
        cfg.sparsity
            .validate()
            .map_err(|e| anyhow::Error::from(EngineError::InvalidSparsity(e)))?;
        let weights = cfg.resolve_weights()?;
        let resolved = cfg.resolved_precision(&weights)?;
        let mut report = scnn::analyze::analyze_engine_config(&cfg, &resolved);
        if !registry.tenants().is_empty() || pool_queue_depth > 0 {
            // The hardware model (gate-level channel characterization) is
            // only consulted when a tenant actually carries a sustained
            // quota to weigh against it.
            let est = registry
                .tenants()
                .iter()
                .any(|t| t.rps > 0.0)
                .then(|| cfg.estimate())
                .flatten();
            report.merge(analyze_deployment(
                shards,
                pool_queue_depth,
                registry.tenants(),
                est.as_ref(),
            ));
        }
        let wall = t.elapsed();
        errors += report.error_count();
        warnings += report.warning_count();
        if json {
            json_items.push(format!(
                "{{\"net\": \"{}\", \"k\": {k}, \"bits\": {bits}, \"errors\": {}, \
                 \"warnings\": {}, \"infos\": {}, \"analysis_us\": {:.1}, \
                 \"diagnostics\": {}}}",
                net.name,
                report.error_count(),
                report.warning_count(),
                report.info_count(),
                wall.as_secs_f64() * 1e6,
                report.render_json()
            ));
        } else {
            println!(
                "{}: {} error(s), {} warning(s), {} info(s) — analyzed in {:.1} µs",
                net.name,
                report.error_count(),
                report.warning_count(),
                report.info_count(),
                wall.as_secs_f64() * 1e6
            );
            print!("{}", report.render_text());
        }
        bench.add(
            &scnn::benchutil::BenchResult {
                name: format!("analyze/{}", net.name),
                median_ns: wall.as_nanos() as f64,
                mean_ns: wall.as_nanos() as f64,
                iters: 1,
            },
            &[
                ("errors", report.error_count() as f64),
                ("warnings", report.warning_count() as f64),
                ("infos", report.info_count() as f64),
            ],
        );
    }
    if json {
        println!("[{}]", json_items.join(", "));
    }
    let out: String = flag(flags, "out", String::new())?;
    if !out.is_empty() {
        bench.write(std::path::Path::new(&out))?;
        if !json {
            println!("wrote {out}");
        }
    }
    if errors > 0 {
        bail!("analysis found {errors} error(s)");
    }
    if deny_warnings && warnings > 0 {
        bail!("analysis found {warnings} warning(s) (--deny-warnings)");
    }
    Ok(())
}

fn simulate(flags: &HashMap<String, String>) -> Result<()> {
    let artifacts = Artifacts::new(flag::<String>(flags, "artifacts", "artifacts".into())?);
    let n: usize = flag(flags, "n", 50)?;
    let kind: BackendKind = flag(flags, "mode", BackendKind::StochasticFused)?;
    if kind == BackendKind::Xla {
        bail!("simulate runs the in-process datapaths; use `serve --backend pjrt`");
    }
    let ds = Dataset::load(&artifacts.dataset("digits"))?;
    check_dataset_fits(&ds, &net_flag(flags)?)?;
    let n = n.min(ds.len());
    let pool = Engine::open_pool(pool_config(kind, &artifacts, flags)?)?;
    let t = Instant::now();
    // One pipelined batch fanned over the shards: each shard's plan
    // (gathers, randoms, weight streams) is compiled once at open — and
    // homogeneous shards share a single plan through the artifact cache.
    let outputs = pool.infer_batch(&ds.images[..n])?;
    let correct = outputs
        .iter()
        .zip(&ds.labels[..n])
        .filter(|(out, &l)| classify(out) == l as usize)
        .count();
    println!(
        "mode={kind}: accuracy {:.2}% ({correct}/{n}) in {:.1} s ({:.1} img/s)",
        100.0 * correct as f64 / n as f64,
        t.elapsed().as_secs_f64(),
        n as f64 / t.elapsed().as_secs_f64()
    );
    print!("{}", pool.metrics().summary());
    Ok(())
}

fn sweep(flags: &HashMap<String, String>) -> Result<()> {
    let tech = parse_tech(&flag::<String>(flags, "tech", "rfet".into())?)?;
    let max: usize = flag(flags, "max-channels", 32)?;
    let k: usize = flag(flags, "k", 32)?;
    let counts: Vec<usize> = (0..).map(|i| 1 << i).take_while(|&c| c <= max).collect();
    let net = net_flag(flags)?;
    println!("{tech} on {}:", net.name);
    println!("ch | area mm² | latency µs | energy µJ | ADP | EDP | EDAP");
    let mut ms = Vec::new();
    for &c in &counts {
        let cfg = apply_precision_flags(
            EngineConfig::new(BackendKind::StochasticFused, net.clone())
                .with_tech(tech)
                .with_channels(c)
                .with_k(k),
            flags,
        )?;
        // Refuse malformed plans with the same typed error serve/simulate
        // raise at open — a bad --k-per-layer must not silently shape the
        // modeled numbers.
        cfg.validate_precision()
            .map_err(|e| anyhow::Error::from(EngineError::InvalidPrecision(e.to_string())))?;
        let est = Engine::estimate(&cfg).ok_or_else(|| {
            anyhow!(
                "no hardware estimate for this configuration (an --k-auto-budget \
                 sweep needs weights — use --k or --k-per-layer here)"
            )
        })?;
        let m = est.metrics;
        println!(
            "{:>2} | {:.4} | {:.2} | {:.3} | {:.4} | {:.4} | {:.5}",
            c,
            m.area_mm2,
            m.latency_us,
            m.energy_uj,
            m.adp(),
            m.edp(),
            m.edap()
        );
        ms.push(m);
    }
    println!("optimal by EDAP: {} channels", counts[argmin_by(&ms, |m| m.edap())]);
    Ok(())
}

fn report(flags: &HashMap<String, String>) -> Result<()> {
    let table: u32 = flag(flags, "table", 1)?;
    match table {
        1 => {
            println!("Table I — component comparison (measured by our Genus-substitute)");
            for tech in [TechKind::Finfet10, TechKind::Rfet10] {
                let lib = scnn::tech::CellLibrary::for_kind(tech);
                let p = channel::characterize_pcc(&lib);
                let a = channel::characterize_apc(&lib);
                println!(
                    "{tech}: PCC8 {:.2} µm² {:.0} ps {:.2} fJ | APC25 {:.2} µm² {:.0} ps {:.2} fJ",
                    p.area_um2, p.delay_ps, p.energy_per_cycle_fj,
                    a.area_um2, a.delay_ps, a.energy_per_cycle_fj
                );
            }
        }
        2 => {
            println!("Table II — channel-level comparison");
            for tech in [TechKind::Finfet10, TechKind::Rfet10] {
                let c = channel::characterize_channel(tech);
                println!(
                    "{tech}: area {:.0} µm², min clock {:.2} ns, energy {:.2} pJ/cycle",
                    c.area_um2,
                    c.min_clock_ps / 1000.0,
                    c.energy_per_cycle_fj / 1000.0
                );
            }
        }
        3 => {
            println!("Table III — This Work (8 channels, LeNet-5 workload)");
            let net = NetworkSpec::lenet5();
            for tech in [TechKind::Finfet10, TechKind::Rfet10] {
                let cfg = EngineConfig::new(BackendKind::StochasticFused, net.clone())
                    .with_tech(tech)
                    .with_channels(8);
                let m = Engine::estimate(&cfg).expect("estimate").metrics;
                println!(
                    "{tech}: {:.3} mm², {:.1} mW, {:.2} GHz, {:.2} TOPS/W, {:.2} TOPS/mm²",
                    m.area_mm2,
                    m.power_mw,
                    m.clock_ghz,
                    m.tops_per_watt(),
                    m.tops_per_mm2()
                );
            }
        }
        other => bail!("unknown table {other}"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(list: &[&str]) -> Vec<String> {
        list.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_space_and_equals_forms() {
        let m = parse_flags(&args(&["--n", "50", "--backend=sc", "--mode=expectation"]));
        assert_eq!(m["n"], "50");
        assert_eq!(m["backend"], "sc");
        assert_eq!(m["mode"], "expectation");
    }

    #[test]
    fn bare_switches_and_following_flags() {
        let m = parse_flags(&args(&["--verbose", "--n", "10", "--fast", "--k=8"]));
        assert_eq!(m["verbose"], "true");
        assert_eq!(m["fast"], "true");
        assert_eq!(m["n"], "10");
        assert_eq!(m["k"], "8");
    }

    #[test]
    fn negative_numeric_values_are_values() {
        let m = parse_flags(&args(&["--offset", "-3", "--gain=-2.5", "--bias", "-0.25"]));
        assert_eq!(m["offset"], "-3");
        assert_eq!(m["gain"], "-2.5");
        assert_eq!(m["bias"], "-0.25");
        assert_eq!(flag::<i64>(&m, "offset", 0).unwrap(), -3);
        assert_eq!(flag::<f64>(&m, "gain", 0.0).unwrap(), -2.5);
    }

    #[test]
    fn equals_value_may_contain_equals_or_be_empty() {
        let m = parse_flags(&args(&["--expr=a=b", "--empty="]));
        assert_eq!(m["expr"], "a=b");
        assert_eq!(m["empty"], "");
    }

    #[test]
    fn flag_errors_on_unparseable_instead_of_defaulting() {
        let m = parse_flags(&args(&["--n", "not-a-number"]));
        assert!(flag::<usize>(&m, "n", 7).is_err(), "must not silently fall back");
        assert_eq!(flag::<usize>(&m, "absent", 7).unwrap(), 7);
    }

    #[test]
    fn net_flag_resolves_through_the_registry() {
        let m = parse_flags(&args(&["--net", "mnist_strided"]));
        assert_eq!(net_flag(&m).unwrap().name, "mnist_strided");
        assert_eq!(net_flag(&parse_flags(&[])).unwrap().name, "lenet5");
        let bad = parse_flags(&args(&["--net", "alexnet"]));
        assert!(net_flag(&bad).is_err());
    }

    #[test]
    fn precision_flags_lower_to_typed_policies() {
        let base = || {
            EngineConfig::new(
                BackendKind::StochasticFused,
                scnn::accel::layers::NetworkSpec::lenet5(),
            )
        };
        // Plain --k stays uniform.
        let cfg = apply_precision_flags(base().with_k(64), &parse_flags(&[])).unwrap();
        assert_eq!(cfg.precision, Precision::Uniform(64));
        // --k-per-layer parses a comma list.
        let m = parse_flags(&args(&["--k-per-layer", "256, 128,64,32,32"]));
        let cfg = apply_precision_flags(base(), &m).unwrap();
        assert_eq!(cfg.precision, Precision::PerLayer(vec![256, 128, 64, 32, 32]));
        // --k-auto-budget lowers to the autotune policy.
        let m = parse_flags(&args(&["--k-auto-budget", "0.05"]));
        let cfg = apply_precision_flags(base(), &m).unwrap();
        assert_eq!(cfg.precision, Precision::Auto { accuracy_budget: 0.05 });
        // Unparseable lists and conflicting flags are errors.
        assert!(parse_k_list("64,banana").is_err());
        let both = parse_flags(&args(&["--k-per-layer=64", "--k-auto-budget=0.1"]));
        assert!(apply_precision_flags(base(), &both).is_err());
        // A malformed per-layer policy is rejected by validate (typed),
        // exactly what the CLI surfaces at open.
        let bad = parse_flags(&args(&["--k-per-layer", "100"]));
        let cfg = apply_precision_flags(
            base().with_quantized(
                scnn::accel::network::QuantizedWeights::synthetic(
                    &scnn::accel::layers::NetworkSpec::lenet5(),
                    8,
                    1,
                )
                .unwrap(),
            ),
            &bad,
        )
        .unwrap();
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("invalid precision policy"), "{err}");
    }

    #[test]
    fn fault_flags_lower_to_a_plan_and_deadline() {
        let base = || {
            EngineConfig::new(
                BackendKind::Expectation,
                scnn::accel::layers::NetworkSpec::lenet5(),
            )
        };
        let m = parse_flags(&args(&[
            "--fault-bit-flip",
            "0.01",
            "--fault-seed",
            "9",
            "--deadline-us",
            "2500",
        ]));
        let cfg = apply_fault_flags(base(), &m).unwrap();
        let f = cfg.faults.expect("a nonzero rate builds a plan");
        assert_eq!(f.seed, 9);
        assert!((f.bit_flip_rate - 0.01).abs() < 1e-12);
        assert_eq!(cfg.deadline, Some(Duration::from_micros(2500)));
        // No fault flags: the clean datapath, no plan, no deadline.
        let clean = apply_fault_flags(base(), &parse_flags(&[])).unwrap();
        assert!(clean.faults.is_none());
        assert!(clean.deadline.is_none());
        // An unparseable rate is an error, not a silent default.
        let bad = parse_flags(&args(&["--fault-sram", "lots"]));
        assert!(apply_fault_flags(base(), &bad).is_err());
    }

    #[test]
    fn sparsity_flags_lower_to_typed_policies() {
        let base = || {
            EngineConfig::new(
                BackendKind::StochasticFused,
                scnn::accel::layers::NetworkSpec::lenet5(),
            )
        };
        // Absent: the dense datapath.
        let cfg = apply_sparsity_flags(base(), &parse_flags(&[])).unwrap();
        assert!(cfg.sparsity.is_off());
        // A threshold flag lowers to an active policy.
        let m = parse_flags(&args(&["--sparsity-threshold", "0.05"]));
        let cfg = apply_sparsity_flags(base(), &m).unwrap();
        assert!((cfg.sparsity.threshold - 0.05).abs() < 1e-12);
        // `--sparsity off` wins over a threshold: the A/B escape hatch.
        let m = parse_flags(&args(&["--sparsity", "off", "--sparsity-threshold", "0.05"]));
        assert!(apply_sparsity_flags(base(), &m).unwrap().sparsity.is_off());
        // Any other --sparsity value is an error, not a silent default.
        let m = parse_flags(&args(&["--sparsity", "on"]));
        assert!(apply_sparsity_flags(base(), &m).is_err());
        // A degenerate threshold passes through the flag layer and fails
        // the typed validator at open, like malformed precision policies.
        let m = parse_flags(&args(&["--sparsity-threshold", "1.5"]));
        let cfg = apply_sparsity_flags(base(), &m).unwrap();
        let err = cfg
            .with_quantized(
                scnn::accel::network::QuantizedWeights::synthetic(
                    &scnn::accel::layers::NetworkSpec::lenet5(),
                    8,
                    1,
                )
                .unwrap(),
            )
            .validate()
            .unwrap_err()
            .to_string();
        assert!(err.contains("invalid sparsity policy"), "{err}");
        // An unparseable threshold is an error too.
        let bad = parse_flags(&args(&["--sparsity-threshold", "lots"]));
        assert!(apply_sparsity_flags(base(), &bad).is_err());
    }

    #[test]
    fn stuck_lists_parse_sites_with_optional_values() {
        assert_eq!(parse_stuck_list("0:24").unwrap(), vec![(0, 24, true)]);
        assert_eq!(parse_stuck_list("1:3:0").unwrap(), vec![(1, 3, false)]);
        assert_eq!(
            parse_stuck_list("0:24, 1:3:0 ,2:0:1").unwrap(),
            vec![(0, 24, true), (1, 3, false), (2, 0, true)]
        );
        for bad in ["0", "0:24:2", "a:1", "0:b", "0:1:yes", ""] {
            assert!(parse_stuck_list(bad).is_err(), "expected error for {bad:?}");
        }
    }

    #[test]
    fn stuck_flag_alone_builds_a_fault_plan() {
        let base = EngineConfig::new(
            BackendKind::Expectation,
            scnn::accel::layers::NetworkSpec::lenet5(),
        );
        let m = parse_flags(&args(&["--fault-stuck", "0:24,1:3:0"]));
        let cfg = apply_fault_flags(base, &m).unwrap();
        let f = cfg.faults.expect("stuck sites alone must build a plan");
        assert_eq!(f.stuck_lanes.len(), 2);
        assert_eq!(
            (f.stuck_lanes[0].wl, f.stuck_lanes[0].lane, f.stuck_lanes[0].stuck_one),
            (0, 24, true)
        );
        assert_eq!(
            (f.stuck_lanes[1].wl, f.stuck_lanes[1].lane, f.stuck_lanes[1].stuck_one),
            (1, 3, false)
        );
        assert!(f.bit_flip_rate.abs() < 1e-12);
    }

    #[test]
    fn backend_kind_flag_round_trips() {
        let m = parse_flags(&args(&["--backend", "reference", "--mode=noisy"]));
        assert_eq!(
            flag::<BackendKind>(&m, "backend", BackendKind::Xla).unwrap(),
            BackendKind::ReferencePerBit
        );
        assert_eq!(
            flag::<BackendKind>(&m, "mode", BackendKind::StochasticFused).unwrap(),
            BackendKind::NoisyExpectation
        );
        assert!(flag::<BackendKind>(&m, "backend", BackendKind::Xla).is_ok());
        let bad = parse_flags(&args(&["--backend", "warp-drive"]));
        assert!(flag::<BackendKind>(&bad, "backend", BackendKind::Xla).is_err());
    }
}
