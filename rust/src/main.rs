//! `scnn` — the L3 coordinator CLI.
//!
//! Subcommands:
//! * `serve`     — load AOT artifacts, serve the synthetic test set through
//!   the dynamic batcher, report accuracy + latency + throughput;
//! * `simulate`  — bit-exact SC inference (full LFSR→PCC→XNOR→APC→B2S→S2B
//!   datapath) over the test set, any bitstream length / precision;
//! * `sweep`     — Fig. 13 channel-count design-space exploration;
//! * `report`    — regenerate the paper's tables (I, II, III) on stdout;
//! * `calibrate` — print raw block characterization (debugging aid).
//!
//! (Hand-rolled flag parsing: clap is not vendored in this offline
//! environment — see the Cargo.toml note.)

use anyhow::{bail, Context, Result};
use scnn::accel::network::{classify, forward_batch, ForwardMode};
use scnn::accel::{channel, layers::NetworkSpec, metrics::argmin_by, system};
use scnn::coordinator::{Coordinator, CoordinatorConfig, ServeBackend};
use scnn::data::{Artifacts, Dataset, ModelWeights};
use scnn::tech::TechKind;
use std::collections::HashMap;
use std::time::{Duration, Instant};

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut m = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                m.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                m.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    m
}

fn flag<T: std::str::FromStr>(flags: &HashMap<String, String>, key: &str, default: T) -> T {
    flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let flags = parse_flags(&args[1.min(args.len())..]);
    match cmd {
        "serve" => serve(&flags),
        "simulate" => simulate(&flags),
        "sweep" => sweep(&flags),
        "report" => report(&flags),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            bail!("unknown command {other:?}")
        }
    }
}

fn print_help() {
    println!(
        "scnn — RFET stochastic-computing NN accelerator (paper reproduction)\n\
         \n\
         USAGE: scnn <command> [--flags]\n\
         \n\
         COMMANDS:\n\
           serve     --artifacts DIR --n N --threads T --backend pjrt|sc\n\
                     serve the test set (PJRT graph or bit-exact SC engine)\n\
           simulate  --mode stochastic|expectation|fixed --k K --bits B --n N\n\
                     batched-parallel bit-exact simulation over the test set\n\
           sweep     --tech rfet|finfet --max-channels C  Fig. 13 design space\n\
           report    --table 1|2|3                        paper tables\n"
    );
}

fn serve(flags: &HashMap<String, String>) -> Result<()> {
    let artifacts = Artifacts::new(flag::<String>(flags, "artifacts", "artifacts".into()));
    let n: usize = flag(flags, "n", 200);
    let threads: usize = flag(flags, "threads", 8);
    let backend_s: String = flag(flags, "backend", "pjrt".into());
    if !artifacts.dataset("digits").exists() {
        bail!("artifacts missing — run `make artifacts` first");
    }
    let ds = Dataset::load(&artifacts.dataset("digits"))?;
    let n = n.min(ds.len());
    let backend = match backend_s.as_str() {
        "pjrt" => {
            if !artifacts.present() {
                bail!("artifacts missing — run `make artifacts` first");
            }
            ServeBackend::Pjrt {
                hlo_ladder: vec![
                    (1, artifacts.hlo("lenet5", 1)),
                    (8, artifacts.hlo("lenet5", 8)),
                    (32, artifacts.hlo("lenet5", 32)),
                ],
            }
        }
        "sc" => {
            // Bit-exact SC serving: one ForwardPlan reused for the whole run.
            let k: usize = flag(flags, "k", 32);
            let bits: u32 = flag(flags, "bits", 8);
            let weights =
                ModelWeights::load(&artifacts.weights("lenet5", "sc"))?.quantize(bits);
            ServeBackend::Stochastic {
                net: NetworkSpec::lenet5(),
                weights,
                mode: ForwardMode::Stochastic { k, seed: 7 },
                batch_max: 32,
            }
        }
        other => bail!("unknown backend {other:?} (pjrt|sc)"),
    };
    let cfg = CoordinatorConfig {
        backend,
        image_len: ds.shape.0 * ds.shape.1 * ds.shape.2,
        image_dims: ds.shape,
        classes: 10,
        linger: Duration::from_millis(2),
    };
    let coord = Coordinator::start(cfg).context("starting coordinator")?;
    let t = Instant::now();
    let preds = coord.infer_all(&ds.images[..n], threads)?;
    let wall = t.elapsed();
    let correct = preds
        .iter()
        .zip(&ds.labels[..n])
        .filter(|(&p, &l)| p == l as usize)
        .count();
    let st = coord.stats();
    println!("served {n} requests in {:.1} ms ({:.0} img/s)", wall.as_secs_f64() * 1e3, n as f64 / wall.as_secs_f64());
    println!("accuracy: {:.2}% ({correct}/{n})", 100.0 * correct as f64 / n as f64);
    println!(
        "latency p50 {} µs, p99 {} µs, mean batch {:.1}",
        st.latency_percentile_us(50.0),
        st.latency_percentile_us(99.0),
        st.mean_batch()
    );
    Ok(())
}

fn simulate(flags: &HashMap<String, String>) -> Result<()> {
    let artifacts = Artifacts::new(flag::<String>(flags, "artifacts", "artifacts".into()));
    let n: usize = flag(flags, "n", 50);
    let k: usize = flag(flags, "k", 32);
    let bits: u32 = flag(flags, "bits", 8);
    let mode_s: String = flag(flags, "mode", "stochastic".into());
    let net = NetworkSpec::lenet5();
    let ds = Dataset::load(&artifacts.dataset("digits"))?;
    let weights = ModelWeights::load(&artifacts.weights("lenet5", "sc"))?.quantize(bits);
    let mode = match mode_s.as_str() {
        "stochastic" => ForwardMode::Stochastic { k, seed: 7 },
        "expectation" => ForwardMode::Expectation,
        "fixed" => ForwardMode::FixedPoint,
        other => bail!("unknown mode {other:?}"),
    };
    let n = n.min(ds.len());
    let t = Instant::now();
    // Batched-parallel forward: the plan (gathers, randoms, weight streams)
    // is compiled once and the images fan out across cores.
    let inputs: Vec<Vec<f64>> = ds.images[..n]
        .iter()
        .map(|img| img.iter().map(|&v| v as f64).collect())
        .collect();
    let outputs = forward_batch(&net, &weights, &inputs, mode);
    let correct = outputs
        .iter()
        .zip(&ds.labels[..n])
        .filter(|(out, &l)| classify(out) == l as usize)
        .count();
    println!(
        "mode={mode_s} k={k} bits={bits}: accuracy {:.2}% ({correct}/{n}) in {:.1} s ({:.1} img/s)",
        100.0 * correct as f64 / n as f64,
        t.elapsed().as_secs_f64(),
        n as f64 / t.elapsed().as_secs_f64()
    );
    Ok(())
}

fn sweep(flags: &HashMap<String, String>) -> Result<()> {
    let tech = match flag::<String>(flags, "tech", "rfet".into()).as_str() {
        "rfet" => TechKind::Rfet10,
        "finfet" => TechKind::Finfet10,
        other => bail!("unknown tech {other:?}"),
    };
    let max: usize = flag(flags, "max-channels", 32);
    let counts: Vec<usize> = (0..).map(|i| 1 << i).take_while(|&c| c <= max).collect();
    let net = NetworkSpec::lenet5();
    let evals = system::sweep_channels(tech, &net, &counts);
    println!("{tech} on {}:", net.name);
    println!("ch | area mm² | latency µs | energy µJ | ADP | EDP | EDAP");
    for e in &evals {
        let m = &e.metrics;
        println!(
            "{:>2} | {:.4} | {:.2} | {:.3} | {:.4} | {:.4} | {:.5}",
            e.channels,
            m.area_mm2,
            m.latency_us,
            m.energy_uj,
            m.adp(),
            m.edp(),
            m.edap()
        );
    }
    let ms: Vec<_> = evals.iter().map(|e| e.metrics).collect();
    println!("optimal by EDAP: {} channels", counts[argmin_by(&ms, |m| m.edap())]);
    Ok(())
}

fn report(flags: &HashMap<String, String>) -> Result<()> {
    let table: u32 = flag(flags, "table", 1);
    match table {
        1 => {
            println!("Table I — component comparison (measured by our Genus-substitute)");
            for tech in [TechKind::Finfet10, TechKind::Rfet10] {
                let lib = scnn::tech::CellLibrary::for_kind(tech);
                let p = channel::characterize_pcc(&lib);
                let a = channel::characterize_apc(&lib);
                println!(
                    "{tech}: PCC8 {:.2} µm² {:.0} ps {:.2} fJ | APC25 {:.2} µm² {:.0} ps {:.2} fJ",
                    p.area_um2, p.delay_ps, p.energy_per_cycle_fj,
                    a.area_um2, a.delay_ps, a.energy_per_cycle_fj
                );
            }
        }
        2 => {
            println!("Table II — channel-level comparison");
            for tech in [TechKind::Finfet10, TechKind::Rfet10] {
                let c = channel::characterize_channel(tech);
                println!(
                    "{tech}: area {:.0} µm², min clock {:.2} ns, energy {:.2} pJ/cycle",
                    c.area_um2,
                    c.min_clock_ps / 1000.0,
                    c.energy_per_cycle_fj / 1000.0
                );
            }
        }
        3 => {
            println!("Table III — This Work (8 channels, LeNet-5 workload)");
            let net = NetworkSpec::lenet5();
            for tech in [TechKind::Finfet10, TechKind::Rfet10] {
                let e = system::evaluate(&system::SystemConfig::paper(tech, 8), &net);
                let m = &e.metrics;
                println!(
                    "{tech}: {:.3} mm², {:.1} mW, {:.2} GHz, {:.2} TOPS/W, {:.2} TOPS/mm²",
                    m.area_mm2,
                    m.power_mw,
                    m.clock_ghz,
                    m.tops_per_watt(),
                    m.tops_per_mm2()
                );
            }
        }
        other => bail!("unknown table {other}"),
    }
    Ok(())
}
