//! `sc-lint` — static analysis over the full configuration space, run
//! WITHOUT executing a single SC cycle.
//!
//! Stochastic-computing correctness hazards are notoriously silent:
//! correlated bitstreams bias every XNOR multiply they feed, an undersized
//! accumulator clips counts instead of overflowing loudly, and a fault
//! plan aimed at a lane that does not exist simply never fires. This
//! module walks the *same* compiled artifacts the kernels execute — the
//! stage IR ([`crate::accel::stage`]), the keyed SNG stream-generation
//! scheme of `accel::network`, the resolved [`PrecisionPlan`], the
//! [`FaultPlan`], and the serving configuration — and proves a set of
//! invariants about them, emitting typed, coded [`Diagnostic`]s where a
//! proof fails.
//!
//! The analyses:
//!
//! * **Stream-correlation lint** (`SC001`/`SC002`) — the engine keys every
//!   SNG stream as `(base, lane)` with `base = seed ^ wl·0x9E37_79B9`:
//!   activation site `p` uses `(base, p)`, padding lane `j` uses
//!   `(base, 2⁴⁰ + j)`, and weight lane `(oc, j)` uses
//!   `(base ^ 0x5EED_CAFE, (oc << 20) + j)`. Two streams feeding one XNOR
//!   are decorrelated iff their keys differ, so the lint proves the key
//!   spaces are disjoint and injective: activation sites stay below the
//!   2⁴⁰ padding offset, and weight-lane packing stays injective only
//!   while `fan_in ≤ 2²⁰` — a wider stage aliases weight lanes across
//!   output channels (`SC001`, Error). Collisions deliberately induced by
//!   [`FaultPlan::correlated_weight_lane`] are *declared* and downgrade to
//!   `SC002` Info, with the exact collapsed-lane count (every draw is a
//!   pure function of the plan seed, so the analyzer enumerates them
//!   without running the datapath).
//! * **Counter-width sufficiency** (`SC003`) — per compute stage, prove
//!   the `m = ⌈log₂(fan_in+1)⌉`-bit APC/`VerticalCounter` planes hold the
//!   per-cycle count, the `2^(m+1)` B2S comparator domain holds the
//!   doubled count `2c`, and the 32-bit `ones` accumulators of the
//!   transposed kernel hold a full stage's cycle count (`k ≤ 2³² − 1`;
//!   tail lanes of the 64-lane bit-plane packing are XNOR identities and
//!   provably contribute zero, so the per-cycle bound is `fan_in`, not the
//!   padded lane count).
//! * **IR dataflow lints** (`SC007`/`SC008`) — every gather-table index
//!   stays inside the stage's input sites; stage shapes chain; residual
//!   `Add{from}` branches reference earlier, saved, shape-compatible
//!   stages; saved branches are actually consumed (a dead save is a
//!   warning, not a crash — it only wastes memory).
//! * **Precision lints** (`SC004`/`SC005`) — a stage `k` below the
//!   `2^bits` quantization resolution floor aliases adjacent codes to one
//!   stream probability (`SC004`, Warning); a degrade-policy `min_k` that
//!   is zero, word-misaligned, or *above* a resolved stage length would
//!   make the first SLO-breach fallback step raise precision (`SC005`).
//! * **Deployment lints** (`SC006`/`SC009`/`SC010`) — fault-plan sites
//!   beyond the compiled stage/lane bounds, tenant aggregate sustained rps
//!   against the modeled pool throughput, and a pool admission queue too
//!   shallow to keep every shard busy.
//! * **Sparsity lints** (`SC011`/`SC012`) — under an active
//!   [`crate::accel::network::SparsityPolicy`], a channel pruned to
//!   fan-in 0 (Error: the plan cannot compile), a surviving fan-in whose
//!   compiled `k` under-resolves the pruned stage's rescaled output
//!   (Warning), and the measured per-stage prune ratios (Info). Inert
//!   when sparsity is off, so the default config stays diagnostic-free.
//!
//! Three consumers: `Engine::open` runs [`analyze_engine_config`] as a
//! pre-flight (errors become [`crate::engine::EngineError::Analysis`],
//! warnings surface in `SessionMetrics::analysis_warnings`); the
//! `scnn analyze` CLI subcommand renders reports as text or JSON over the
//! whole topology zoo; and CI gates every PR on a zero-error,
//! zero-warning pass (`--deny-warnings`).
//!
//! The closed-loop invariant (property-tested in `tests/stage_ir.rs`):
//! any configuration this analyzer passes with **zero errors** runs
//! fused == transposed == reference bit-exact.

#![deny(clippy::unwrap_used)]

use crate::accel::layers::NetworkSpec;
use crate::accel::precision::{PrecisionPlan, WORD};
use crate::accel::stage::{self, StageDescriptor, StageOp};
use crate::engine::{DegradePolicy, EngineConfig, HardwareEstimate};
use crate::faults::FaultPlan;
use crate::sc::neuron;
use crate::serve::Tenant;
use std::fmt;

/// The weight-lane key packs `(oc, j)` as `(oc << 20) + j`; injectivity
/// (and therefore pairwise stream decorrelation) holds only while every
/// fan-in index fits the shift.
pub const WEIGHT_LANE_SPAN: usize = 1 << 20;

/// Padding lanes are keyed at `2^40 + j`, so activation site indices must
/// stay below this offset to keep the two families disjoint.
pub const PAD_LANE_OFFSET: u64 = 1 << 40;

/// Diagnostic severity, ordered `Info < Warning < Error`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    /// Expected/declared behavior worth surfacing (e.g. collisions the
    /// fault plan asked for).
    Info,
    /// Suspicious but runnable; `--deny-warnings` promotes these to
    /// failures in CI.
    Warning,
    /// The configuration is wrong; `Engine::open` refuses it.
    Error,
}

impl Severity {
    /// Lowercase label used by the text and JSON renderings.
    pub fn label(self) -> &'static str {
        match self {
            Severity::Info => "info",
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.label())
    }
}

/// One coded finding. `code` is stable (`SC001`..) so tests, CI gates, and
/// humans can match on it; `stage`/`lane` locate the finding when it has a
/// span; `suggested_fix` says what to change, not just what is wrong.
#[derive(Debug, Clone, PartialEq)]
pub struct Diagnostic {
    /// Stable diagnostic code (`SC001`..`SC012`, `SC000` for an invalid
    /// network/plan).
    pub code: &'static str,
    /// How bad it is.
    pub severity: Severity,
    /// Layer index in the [`NetworkSpec`] the finding is anchored to.
    pub stage: Option<usize>,
    /// Fan-in lane index, when the finding names one.
    pub lane: Option<usize>,
    /// What is wrong (one sentence, self-contained).
    pub message: String,
    /// What to change to make it pass.
    pub suggested_fix: Option<String>,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}[{}]", self.severity, self.code)?;
        if let Some(s) = self.stage {
            write!(f, " stage {s}")?;
        }
        if let Some(l) = self.lane {
            write!(f, " lane {l}")?;
        }
        write!(f, ": {}", self.message)
    }
}

/// An analysis result: every diagnostic, ordered worst-first.
#[derive(Debug, Clone, Default)]
pub struct Report {
    diags: Vec<Diagnostic>,
}

impl Report {
    /// An empty (all-clear) report.
    pub fn new() -> Self {
        Report::default()
    }

    fn push(
        &mut self,
        code: &'static str,
        severity: Severity,
        stage: Option<usize>,
        lane: Option<usize>,
        message: String,
        fix: Option<String>,
    ) {
        self.diags.push(Diagnostic { code, severity, stage, lane, message, suggested_fix: fix });
    }

    /// Fold another report's diagnostics into this one.
    pub fn merge(&mut self, other: Report) {
        self.diags.extend(other.diags);
    }

    /// Every diagnostic, errors first (stable within a severity).
    pub fn diagnostics(&self) -> Vec<&Diagnostic> {
        let mut v: Vec<&Diagnostic> = self.diags.iter().collect();
        v.sort_by(|a, b| b.severity.cmp(&a.severity));
        v
    }

    /// Diagnostics at exactly `severity`.
    pub fn at(&self, severity: Severity) -> impl Iterator<Item = &Diagnostic> {
        self.diags.iter().filter(move |d| d.severity == severity)
    }

    /// Number of `Error` diagnostics.
    pub fn error_count(&self) -> usize {
        self.at(Severity::Error).count()
    }

    /// Number of `Warning` diagnostics.
    pub fn warning_count(&self) -> usize {
        self.at(Severity::Warning).count()
    }

    /// Number of `Info` diagnostics.
    pub fn info_count(&self) -> usize {
        self.at(Severity::Info).count()
    }

    /// True when any diagnostic is an `Error`.
    pub fn has_errors(&self) -> bool {
        self.error_count() > 0
    }

    /// True when a given code was emitted at any severity.
    pub fn has_code(&self, code: &str) -> bool {
        self.diags.iter().any(|d| d.code == code)
    }

    /// One line per error, `; `-joined — the payload of
    /// [`crate::engine::EngineError::Analysis`].
    pub fn error_summary(&self) -> String {
        self.at(Severity::Error).map(|d| d.to_string()).collect::<Vec<_>>().join("; ")
    }

    /// Human-readable rendering: one line per diagnostic (worst first)
    /// plus an indented fix line where one is suggested.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for d in self.diagnostics() {
            out.push_str(&d.to_string());
            out.push('\n');
            if let Some(fix) = &d.suggested_fix {
                out.push_str(&format!("  fix: {fix}\n"));
            }
        }
        out
    }

    /// Machine-readable rendering: a JSON array of diagnostic objects
    /// (hand-rolled — serde is not vendored in this offline environment).
    pub fn render_json(&self) -> String {
        let items: Vec<String> = self
            .diagnostics()
            .iter()
            .map(|d| {
                let mut fields = vec![
                    format!("\"code\": \"{}\"", d.code),
                    format!("\"severity\": \"{}\"", d.severity.label()),
                ];
                if let Some(s) = d.stage {
                    fields.push(format!("\"stage\": {s}"));
                }
                if let Some(l) = d.lane {
                    fields.push(format!("\"lane\": {l}"));
                }
                fields.push(format!("\"message\": \"{}\"", json_escape(&d.message)));
                if let Some(fix) = &d.suggested_fix {
                    fields.push(format!("\"suggested_fix\": \"{}\"", json_escape(fix)));
                }
                format!("{{{}}}", fields.join(", "))
            })
            .collect();
        format!("[{}]", items.join(", "))
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Analyze a network under a resolved per-layer precision plan, an
/// optional fault plan, and the quantization width. Never executes the
/// datapath and never panics: an invalid network or a plan that does not
/// fit it becomes an `SC000` Error diagnostic instead.
pub fn analyze_network(
    net: &NetworkSpec,
    precision: &PrecisionPlan,
    bits: u32,
    faults: Option<&FaultPlan>,
) -> Report {
    let mut r = Report::new();
    let stages = match net.stages() {
        Ok(s) => s,
        Err(e) => {
            r.push(
                "SC000",
                Severity::Error,
                None,
                None,
                format!("network {:?} fails shape validation: {e:#}", net.name),
                Some("fix the layer stack so NetworkSpec::validate accepts it".into()),
            );
            return r;
        }
    };
    let n_compute = stages.iter().filter(|s| s.is_compute()).count();
    if let Err(e) = precision.validate_for(n_compute) {
        r.push(
            "SC000",
            Severity::Error,
            None,
            None,
            format!("precision plan does not fit network {:?}: {e}", net.name),
            Some(format!(
                "supply one positive multiple of {WORD} per compute layer ({n_compute} here)"
            )),
        );
        return r;
    }
    r.merge(analyze_stages(&stages, precision, bits, faults));
    r
}

/// Analyze an already-compiled stage chain (the lower-level entry point —
/// tests use it to probe hand-built descriptor lists the high-level
/// [`NetworkSpec::stages`] compiler would never emit).
pub fn analyze_stages(
    stages: &[StageDescriptor],
    precision: &PrecisionPlan,
    bits: u32,
    faults: Option<&FaultPlan>,
) -> Report {
    let mut r = Report::new();
    lint_dataflow(&mut r, stages);
    for st in stages.iter().filter(|s| s.is_compute()) {
        lint_compute_stage(&mut r, st, precision, bits, faults);
    }
    lint_fault_sites(&mut r, stages, faults);
    r
}

/// `SC007`/`SC008`: gather bounds, stage chaining, residual dataflow.
fn lint_dataflow(r: &mut Report, stages: &[StageDescriptor]) {
    let mut consumed = vec![false; stages.len()];
    for (i, st) in stages.iter().enumerate() {
        if st.index != i {
            r.push(
                "SC008",
                Severity::Error,
                Some(i),
                None,
                format!("stage at position {i} carries index {} — the chain is not contiguous", st.index),
                Some("renumber the stage descriptors 0..n in execution order".into()),
            );
        }
        if let Some(next) = stages.get(i + 1) {
            if st.out_shape != next.in_shape {
                r.push(
                    "SC008",
                    Severity::Error,
                    Some(i),
                    None,
                    format!(
                        "stage {i} ({}) emits {:?} but stage {} consumes {:?} — shapes do not chain",
                        st.label(),
                        st.out_shape,
                        i + 1,
                        next.in_shape
                    ),
                    Some("make each stage's out_shape the next stage's in_shape".into()),
                );
            }
        }
        if let StageOp::Add { from } = st.op {
            if from >= i {
                r.push(
                    "SC008",
                    Severity::Error,
                    Some(i),
                    None,
                    format!("residual add at stage {i} references stage {from}, which is not earlier"),
                    Some("point Add{from} at an already-executed stage".into()),
                );
            } else {
                consumed[from] = true;
                if !stages[from].save_output {
                    r.push(
                        "SC008",
                        Severity::Error,
                        Some(i),
                        None,
                        format!(
                            "residual add at stage {i} reads stage {from}, whose output is never saved"
                        ),
                        Some(format!("mark stage {from} save_output so the branch survives")),
                    );
                }
                if stages[from].out_shape != st.in_shape {
                    r.push(
                        "SC008",
                        Severity::Error,
                        Some(i),
                        None,
                        format!(
                            "residual add at stage {i} merges {:?} into {:?} — branch shapes differ",
                            stages[from].out_shape, st.in_shape
                        ),
                        Some("merge only branches with identical output shapes".into()),
                    );
                }
            }
        }
    }
    for (i, st) in stages.iter().enumerate() {
        if st.save_output && !consumed[i] {
            r.push(
                "SC008",
                Severity::Warning,
                Some(i),
                None,
                format!(
                    "stage {i} ({}) saves its output but no later residual add consumes it — a dead branch holding {} values alive",
                    st.label(),
                    st.out_len()
                ),
                Some("drop save_output (or the vestigial Add that once read it)".into()),
            );
        }
    }
    // Gather-table bounds proof: every window index addresses a real input
    // site of its stage.
    for st in stages.iter().filter(|s| s.is_compute()) {
        if let Some(table) = stage::gather(st) {
            let in_len = st.in_len();
            'windows: for (wi, window) in table.windows.iter().enumerate() {
                for (j, site) in window.iter().enumerate() {
                    if let Some(p) = site {
                        if *p >= in_len {
                            r.push(
                                "SC007",
                                Severity::Error,
                                Some(st.index),
                                Some(j),
                                format!(
                                    "gather window {wi} reads input site {p} but stage {} has only {in_len} sites",
                                    st.index
                                ),
                                Some("regenerate the gather table from the stage geometry".into()),
                            );
                            break 'windows; // one proof failure per stage is plenty
                        }
                    }
                }
            }
        }
    }
}

/// The per-compute-stage lints: stream-key injectivity (`SC001`),
/// declared correlation collisions (`SC002`), counter/accumulator width
/// (`SC003`), and the quantization resolution floor (`SC004`).
fn lint_compute_stage(
    r: &mut Report,
    st: &StageDescriptor,
    precision: &PrecisionPlan,
    bits: u32,
    faults: Option<&FaultPlan>,
) {
    let Some(wl) = st.weight_layer else {
        r.push(
            "SC008",
            Severity::Error,
            Some(st.index),
            None,
            format!("compute stage {} carries no weight-layer index", st.index),
            Some("number the compute stages' weight layers contiguously".into()),
        );
        return;
    };
    let Some((out_ch, fan_in)) = st.weight_shape() else {
        return;
    };
    // `PrecisionPlan::k_for` panics out of range; the analyzer must not.
    let Some(&k) = precision.ks().get(wl) else {
        r.push(
            "SC000",
            Severity::Error,
            Some(st.index),
            None,
            format!(
                "precision plan covers {} compute layers but stage {} is weight layer {wl}",
                precision.len(),
                st.index
            ),
            Some("supply one stream length per compute layer".into()),
        );
        return;
    };

    // --- SC001: stream-key injectivity. The three key families feeding a
    // stage's XNORs are (base, p) for activation sites, (base, 2^40 + j)
    // for padding lanes, and (base ^ 0x5EED_CAFE, (oc << 20) + j) for
    // weight lanes. The weight family is base-disjoint from the other two
    // (the XOR constant is nonzero), activation/padding stay disjoint
    // while every site index is below 2^40, and the weight-lane packing is
    // injective only while fan_in fits the 20-bit shift.
    if fan_in > WEIGHT_LANE_SPAN {
        r.push(
            "SC001",
            Severity::Error,
            Some(st.index),
            Some(WEIGHT_LANE_SPAN),
            format!(
                "stage {} fan-in {fan_in} exceeds the 2^20 weight-lane key span: lane (oc, j) and \
                 (oc+1, j-2^20) generate from the SAME LFSR state, correlating XNOR products \
                 across output channels",
                st.index
            ),
            Some(format!(
                "keep compute-stage fan-in at or below {WEIGHT_LANE_SPAN}, or widen the lane-key \
                 packing shift in build_layer_plan AND reference::lane_stream together"
            )),
        );
    }
    if st.in_len() as u64 >= PAD_LANE_OFFSET {
        r.push(
            "SC001",
            Severity::Error,
            Some(st.index),
            None,
            format!(
                "stage {} has {} input sites, reaching the 2^40 padding-lane key offset: an \
                 activation stream and a padding stream would share one SNG key",
                st.index,
                st.in_len()
            ),
            Some("shrink the stage input or raise the padding-lane key offset".into()),
        );
    }

    // --- SC002: declared correlation collisions. Every
    // FaultPlan::correlated_weight_lane draw is a pure function of (plan
    // seed, wl, oc, j), so the exact set of collapsed lanes is known
    // statically. Declared means Info, not Error — the closed-loop
    // bit-exactness contract still holds because fused, transposed, and
    // reference all honor the same collapsed keys.
    if let Some(f) = faults.filter(|f| f.sng_correlation_rate > 0.0) {
        let lanes = out_ch * fan_in;
        let collapsed = (0..out_ch)
            .flat_map(|oc| (0..fan_in).map(move |j| (oc, j)))
            .filter(|&(oc, j)| f.correlated_weight_lane(wl, oc, j))
            .count();
        if collapsed > 0 {
            r.push(
                "SC002",
                Severity::Info,
                Some(st.index),
                None,
                format!(
                    "fault plan (seed {}, sng_correlation_rate {}) collapses {collapsed}/{lanes} \
                     weight lanes of stage {} onto the raw activation RNS — declared correlated \
                     XNOR products",
                    f.seed,
                    f.sng_correlation_rate,
                    st.index
                ),
                Some("intended by the fault plan; drop with_sng_correlation_rate to restore \
                      per-lane decorrelation"
                    .into()),
            );
        }
    }

    // --- SC003: counter-width sufficiency. m = ceil(log2(fan_in + 1))
    // plans hold per-cycle counts in [0, fan_in]; the B2S comparator works
    // in the doubled 2^(m+1) domain; and the transposed kernel's per-
    // neuron `ones` accumulator is 32-bit. Transposed tail lanes (the
    // 64-lane padding above fan_in) are XNOR identities contributing zero,
    // so fan_in — not the padded lane count — is the true per-cycle bound.
    if fan_in == 0 {
        r.push(
            "SC003",
            Severity::Error,
            Some(st.index),
            None,
            format!("compute stage {} has zero fan-in — no counter width is meaningful", st.index),
            Some("give the stage at least one input lane".into()),
        );
        return;
    }
    let m = neuron::m_bits(fan_in);
    if (fan_in as u64) > (1u64 << m) - 1 {
        r.push(
            "SC003",
            Severity::Error,
            Some(st.index),
            None,
            format!(
                "stage {}: an {m}-bit counter holds at most {} but the per-cycle count reaches \
                 fan-in {fan_in}",
                st.index,
                (1u64 << m) - 1
            ),
            Some("widen the APC/VerticalCounter planes to ceil(log2(fan_in + 1)) bits".into()),
        );
    }
    if 2 * (fan_in as u64) >= 1u64 << (m + 1) {
        r.push(
            "SC003",
            Severity::Error,
            Some(st.index),
            None,
            format!(
                "stage {}: the 2^{} B2S comparator domain cannot represent the doubled count \
                 2·{fan_in}",
                st.index,
                m + 1
            ),
            Some("widen the B2S comparator to m+1 bits for m = ceil(log2(fan_in + 1))".into()),
        );
    }
    if k as u64 > u32::MAX as u64 {
        r.push(
            "SC003",
            Severity::Error,
            Some(st.index),
            None,
            format!(
                "stage {} bitstream length k={k} overflows the 32-bit B2S `ones` accumulator \
                 (at most {} cycles can be counted)",
                st.index,
                u32::MAX
            ),
            Some(format!(
                "cap the stage's planned k at {} (the word-aligned 32-bit maximum)",
                (u32::MAX as usize / WORD) * WORD
            )),
        );
    }

    // --- SC004: quantization resolution floor. A k-cycle stream resolves
    // probabilities on a 1/k grid; below 2^bits cycles, adjacent quantized
    // codes alias to the same stream and the extra weight precision is
    // silently thrown away.
    let floor = 1usize << bits.min(31);
    if k < floor {
        r.push(
            "SC004",
            Severity::Warning,
            Some(st.index),
            None,
            format!(
                "stage {} runs k={k} cycles below the 2^{bits}={floor} quantization resolution \
                 floor — adjacent {bits}-bit codes alias to the same stream probability",
                st.index
            ),
            Some(format!("raise the stage's k to at least {floor}, or lower --bits")),
        );
    }
}

/// `SC006`: fault-plan sites beyond the compiled stage/lane bounds. The
/// analyzer warns (the sites simply never fire);
/// `ForwardPlan::compile_with_precision_faults` rejects the same sites
/// with a typed error via [`FaultPlan::validate_sites`].
fn lint_fault_sites(r: &mut Report, stages: &[StageDescriptor], faults: Option<&FaultPlan>) {
    let Some(f) = faults else { return };
    let fan_ins: Vec<(usize, usize)> = stages
        .iter()
        .filter(|s| s.is_compute())
        .filter_map(|s| Some((s.index, s.weight_shape()?.1)))
        .collect();
    for s in &f.stuck_lanes {
        match fan_ins.get(s.wl) {
            None => r.push(
                "SC006",
                Severity::Warning,
                None,
                Some(s.lane),
                format!(
                    "fault plan pins a stuck lane on compute layer {} but the network has only \
                     {} compute layers — the site can never fire",
                    s.wl,
                    fan_ins.len()
                ),
                Some(format!("target a compute layer below {}", fan_ins.len())),
            ),
            Some(&(stage_idx, fan_in)) if s.lane >= fan_in => r.push(
                "SC006",
                Severity::Warning,
                Some(stage_idx),
                Some(s.lane),
                format!(
                    "fault plan pins stuck lane {} on compute layer {} (stage {stage_idx}) whose \
                     fan-in is only {fan_in} — the site can never fire",
                    s.lane, s.wl
                ),
                Some(format!("pick a lane below {fan_in}")),
            ),
            Some(_) => {}
        }
    }
}

/// Analyze a full engine configuration against its **resolved** precision
/// plan: the network/stage lints plus the degrade-policy compatibility
/// check (`SC005`). The k-dependent lints are skipped for the analytic
/// backends, whose arithmetic never samples a stream.
pub fn analyze_engine_config(cfg: &EngineConfig, resolved: &PrecisionPlan) -> Report {
    let faults = cfg.faults.as_ref().filter(|f| !f.is_noop());
    let mut r = if cfg.k_sensitive() {
        analyze_network(&cfg.net, resolved, cfg.bits, faults)
    } else {
        // Analytic datapaths own no k: run the structural lints under a
        // nominal full-resolution plan so SC003/SC004 cannot misfire.
        let nominal =
            PrecisionPlan::uniform(1usize << cfg.bits.min(16), cfg.net.n_compute().max(1));
        analyze_network(&cfg.net, &nominal, cfg.bits, faults)
    };
    if let Some(policy) = &cfg.degrade {
        lint_degrade_policy(&mut r, policy, resolved, cfg.k_sensitive());
    }
    lint_sparsity(&mut r, cfg, resolved);
    r
}

/// `SC011`/`SC012`: sparsity-pruning lints over the resolved weights.
/// Inert when the policy is off (the default config must stay
/// diagnostic-free), so these fire only for sessions that opted into
/// pruning. `SC011` is an Error when a channel loses every lane (the
/// plan cannot compile), a Warning when a channel's surviving fan-in is
/// small enough that the compiled `k` under-resolves the pruned stage
/// relative to the dense resolution floor; `SC012` is an Info line per
/// pruned stage with the measured prune ratio.
fn lint_sparsity(r: &mut Report, cfg: &EngineConfig, resolved: &PrecisionPlan) {
    if cfg.sparsity.is_off() || cfg.sparsity.validate().is_err() {
        return;
    }
    let Ok(weights) = cfg.resolve_weights() else {
        return; // unresolvable weights are their own open-time error
    };
    let threshold = cfg.sparsity.threshold;
    let stats = crate::accel::network::prune_stats(&weights, cfg.sparsity);
    for (wl, st) in stats.iter().enumerate() {
        if st.lanes == 0 {
            continue;
        }
        if st.min_fan_in == 0 {
            r.push(
                "SC011",
                Severity::Error,
                Some(wl),
                None,
                format!(
                    "sparsity threshold {threshold} prunes a channel of weight layer {wl} to \
                     fan-in 0 — the channel has no surviving lanes to accumulate"
                ),
                Some("lower --sparsity-threshold so every channel keeps at least one lane".into()),
            );
            continue;
        }
        if st.pruned == 0 {
            continue;
        }
        r.push(
            "SC012",
            Severity::Info,
            Some(wl),
            None,
            format!(
                "sparsity threshold {threshold} prunes {}/{} weight lanes of layer {wl} \
                 ({:.1}% density, smallest surviving fan-in {})",
                st.pruned,
                st.lanes,
                100.0 * st.density(),
                st.min_fan_in
            ),
            None,
        );
        // Resolution floor under pruning: the pruned channel averages over
        // min_fan_in lanes where the dense stage averaged over fan_in, so
        // the k-cycle stream must over-resolve by the same ratio to keep
        // the dense floor (SC004's 2^bits) after rescaling — i.e. warn
        // when min_fan_in · k < fan_in · 2^bits.
        if cfg.k_sensitive() {
            let k = resolved.ks().get(wl).copied().unwrap_or(0);
            let floor = 1u64 << u64::from(weights.bits.min(31));
            if (st.min_fan_in as u64) * (k as u64) < (st.fan_in as u64) * floor {
                r.push(
                    "SC011",
                    Severity::Warning,
                    Some(wl),
                    None,
                    format!(
                        "weight layer {wl}'s smallest surviving fan-in {} runs k={k} cycles \
                         below its pruned resolution floor ({} dense lanes × 2^{} = {} \
                         lane-cycles) — the pruned channel under-resolves its rescaled output",
                        st.min_fan_in,
                        st.fan_in,
                        weights.bits,
                        (st.fan_in as u64) * floor
                    ),
                    Some(format!(
                        "raise the stage's k to at least {}, or lower --sparsity-threshold",
                        ((st.fan_in as u64) * floor).div_ceil(st.min_fan_in as u64)
                    )),
                );
            }
        }
    }
}

/// `SC005`: degrade-policy `min_k` compatibility with the resolved plan.
fn lint_degrade_policy(
    r: &mut Report,
    policy: &DegradePolicy,
    resolved: &PrecisionPlan,
    k_sensitive: bool,
) {
    if policy.min_k == 0 || policy.min_k % WORD != 0 {
        r.push(
            "SC005",
            Severity::Error,
            None,
            None,
            format!(
                "degrade policy min_k={} is not a positive multiple of the {WORD}-cycle word — \
                 degraded plans would fail precision validation",
                policy.min_k
            ),
            Some(format!("set min_k to a positive multiple of {WORD}")),
        );
        return;
    }
    if !k_sensitive {
        return;
    }
    if resolved.ks().iter().any(|&k| k < policy.min_k) {
        r.push(
            "SC005",
            Severity::Error,
            None,
            None,
            format!(
                "degrade policy min_k={} exceeds a resolved stage length (plan {:?}) — the first \
                 SLO-breach fallback would RAISE precision instead of shedding work",
                policy.min_k,
                resolved.ks()
            ),
            Some("lower min_k to at most the smallest resolved stage k".into()),
        );
    } else if resolved.ks().iter().all(|&k| k <= policy.min_k) {
        r.push(
            "SC005",
            Severity::Warning,
            None,
            None,
            format!(
                "degrade policy min_k={} already equals every resolved stage length — the policy \
                 can never shed precision under an SLO breach",
                policy.min_k
            ),
            Some("lower min_k (or raise the plan) so degradation has somewhere to go".into()),
        );
    }
}

/// Deployment lints over the serving configuration: tenant aggregate
/// sustained rps against the modeled pool throughput (`SC009`) and the
/// pool admission queue depth against the shard count (`SC010`). The
/// estimate is optional — without one (e.g. the XLA backend) the
/// throughput lint is skipped rather than guessed.
pub fn analyze_deployment(
    shards: usize,
    pool_queue_depth: usize,
    tenants: &[Tenant],
    estimate: Option<&HardwareEstimate>,
) -> Report {
    let mut r = Report::new();
    if pool_queue_depth > 0 && shards > 0 && pool_queue_depth < shards {
        r.push(
            "SC010",
            Severity::Warning,
            None,
            None,
            format!(
                "pool admission queue depth {pool_queue_depth} is below the shard count {shards} \
                 — admission control can never keep every shard busy"
            ),
            Some(format!(
                "raise the pool queue depth to at least {shards} (0 = sum of shard depths)"
            )),
        );
    }
    let aggregate_rps: f64 = tenants.iter().map(|t| t.rps).filter(|r| *r > 0.0).sum();
    if aggregate_rps > 0.0 {
        if let Some(est) = estimate {
            let latency_us = est.metrics.latency_us;
            if latency_us > 0.0 {
                let capacity = shards.max(1) as f64 * 1e6 / latency_us;
                if aggregate_rps > capacity {
                    r.push(
                        "SC009",
                        Severity::Warning,
                        None,
                        None,
                        format!(
                            "tenant aggregate sustained quota {aggregate_rps:.0} rps exceeds the \
                             modeled pool throughput {capacity:.0} rps ({:.2} µs modeled \
                             inference × {} shard{})",
                            latency_us,
                            shards.max(1),
                            if shards == 1 { "" } else { "s" }
                        ),
                        Some("add shards, lower the tenants' rps quotas, or shrink the \
                              per-layer k so the modeled inference gets faster"
                            .into()),
                    );
                }
            }
        }
    }
    r
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::accel::layers::{LayerKind, LayerSpec};
    use crate::engine::BackendKind;

    fn dense_net(inputs: usize, outputs: usize) -> NetworkSpec {
        NetworkSpec {
            name: format!("dense-{inputs}x{outputs}"),
            input: (1, 1, inputs),
            layers: vec![LayerSpec::linear(LayerKind::Dense { inputs, outputs })],
        }
    }

    #[test]
    fn shipped_topologies_are_clean_at_the_resolution_floor() {
        for name in NetworkSpec::NAMES {
            let net = NetworkSpec::by_name(name).unwrap();
            let plan = PrecisionPlan::uniform(256, net.n_compute());
            let r = analyze_network(&net, &plan, 8, None);
            assert_eq!(r.error_count(), 0, "{name}: {}", r.render_text());
            assert_eq!(r.warning_count(), 0, "{name}: {}", r.render_text());
        }
    }

    #[test]
    fn weight_lane_key_aliasing_is_flagged_sc001() {
        let net = dense_net(WEIGHT_LANE_SPAN + 1, 2);
        let plan = PrecisionPlan::uniform(32, 1);
        let r = analyze_network(&net, &plan, 8, None);
        assert!(r.has_code("SC001"), "{}", r.render_text());
        assert!(r.has_errors());
    }

    #[test]
    fn ones_accumulator_overflow_is_flagged_sc003() {
        let net = dense_net(16, 4);
        let k = ((u32::MAX as usize) + 1 + WORD) / WORD * WORD; // > 2^32, word-aligned
        let plan = PrecisionPlan::uniform(k, 1);
        let r = analyze_network(&net, &plan, 8, None);
        assert!(r.has_code("SC003"), "{}", r.render_text());
        assert!(r.has_errors());
        assert!(!r.has_code("SC001"), "distinct code from the collision lint");
    }

    #[test]
    fn resolution_floor_warning_sc004_is_a_warning_not_an_error() {
        let net = dense_net(16, 4);
        let r = analyze_network(&net, &PrecisionPlan::uniform(32, 1), 8, None);
        assert!(r.has_code("SC004"), "{}", r.render_text());
        assert_eq!(r.error_count(), 0);
        assert!(r.warning_count() > 0);
    }

    #[test]
    fn declared_correlation_downgrades_to_info_sc002() {
        let net = dense_net(16, 4);
        let plan = PrecisionPlan::uniform(256, 1);
        let f = FaultPlan::new(3).with_sng_correlation_rate(0.5);
        let r = analyze_network(&net, &plan, 8, Some(&f));
        assert!(r.has_code("SC002"), "{}", r.render_text());
        assert_eq!(r.error_count(), 0, "declared collisions are not errors");
        assert!(r.info_count() > 0);
    }

    #[test]
    fn fault_sites_beyond_bounds_warn_sc006() {
        let net = dense_net(16, 4);
        let plan = PrecisionPlan::uniform(256, 1);
        let f = FaultPlan::new(1)
            .with_stuck_lane(0, 16, true) // lane beyond fan-in
            .with_stuck_lane(5, 0, false) // layer beyond the network
            .with_stuck_lane(0, 3, true); // in bounds
        let r = analyze_network(&net, &plan, 8, Some(&f));
        assert_eq!(r.at(Severity::Warning).filter(|d| d.code == "SC006").count(), 2);
        assert_eq!(r.error_count(), 0);
    }

    #[test]
    fn dead_saved_branch_and_bad_residuals_are_flagged_sc008() {
        let net = NetworkSpec::mnist_strided();
        let mut stages = net.stages().unwrap();
        // Orphan the saved residual source by retargeting the add.
        for st in &mut stages {
            if let StageOp::Add { from } = &mut st.op {
                *from = 1;
            }
        }
        let plan = PrecisionPlan::uniform(256, net.n_compute());
        let r = analyze_stages(&stages, &plan, 8, None);
        assert!(r.has_code("SC008"), "{}", r.render_text());
        assert!(r.has_errors(), "reading a never-saved branch is an error");
        assert!(
            r.at(Severity::Warning).any(|d| d.code == "SC008"),
            "the orphaned save is a dead-branch warning: {}",
            r.render_text()
        );
    }

    #[test]
    fn gather_bounds_violations_are_flagged_sc007() {
        let net = dense_net(16, 4);
        let mut stages = net.stages().unwrap();
        // A dense stage gathers sites 0..16; shrink the claimed input so
        // the (unchanged) gather table indexes out of bounds.
        stages[0].in_shape = (1, 1, 8);
        let plan = PrecisionPlan::uniform(256, 1);
        let r = analyze_stages(&stages, &plan, 8, None);
        assert!(r.has_code("SC007"), "{}", r.render_text());
        assert!(r.has_errors());
    }

    #[test]
    fn degrade_policy_lints_sc005() {
        let net = dense_net(16, 4);
        let base = EngineConfig::new(BackendKind::StochasticFused, net.clone()).with_k(64);
        let resolved = PrecisionPlan::uniform(64, 1);
        // Misaligned floor: error.
        let cfg = base.clone().with_degrade(DegradePolicy {
            min_k: 13,
            ..DegradePolicy::default()
        });
        let r = analyze_engine_config(&cfg, &resolved);
        assert!(r.at(Severity::Error).any(|d| d.code == "SC005"), "{}", r.render_text());
        // Floor above the plan: error (degrading would raise precision).
        let cfg = base.clone().with_degrade(DegradePolicy {
            min_k: 128,
            ..DegradePolicy::default()
        });
        let r = analyze_engine_config(&cfg, &resolved);
        assert!(r.at(Severity::Error).any(|d| d.code == "SC005"), "{}", r.render_text());
        // Floor equal to the whole plan: inert policy, warning.
        let cfg = base.clone().with_degrade(DegradePolicy {
            min_k: 64,
            ..DegradePolicy::default()
        });
        let r = analyze_engine_config(&cfg, &resolved);
        assert!(r.at(Severity::Warning).any(|d| d.code == "SC005"), "{}", r.render_text());
        // A sane policy below the plan is clean.
        let cfg = base.with_degrade(DegradePolicy { min_k: 8, ..DegradePolicy::default() });
        let r = analyze_engine_config(&cfg, &resolved);
        assert!(!r.has_code("SC005"), "{}", r.render_text());
    }

    #[test]
    fn sparsity_lints_sc011_sc012() {
        use crate::accel::network::{LayerWeights, QuantizedWeights, SparsityPolicy};
        use crate::sc::quantize_bipolar;

        // 3 output channels × 4 lanes: channel `oc` holds the bipolar
        // values (oc+j)/6 for j in 0..4, so oc 0 carries one exact zero
        // and every channel keeps its largest lane under mild pruning.
        let bits = 8;
        let codes: Vec<Vec<u32>> = (0..3)
            .map(|oc| (0..4).map(|j| quantize_bipolar((oc + j) as f64 / 6.0, bits)).collect())
            .collect();
        let weights = QuantizedWeights {
            bits,
            layers: vec![LayerWeights { codes, gamma: 1.0, mu: 0.0 }],
        };
        let net = dense_net(4, 3);
        let base = EngineConfig::new(BackendKind::StochasticFused, net)
            .with_quantized(weights)
            .with_k(256);

        // Sparsity off: the sparsity lints are inert (default configs must
        // stay diagnostic-free for the CI --deny-warnings gate).
        let resolved = PrecisionPlan::uniform(256, 1);
        let r = analyze_engine_config(&base, &resolved);
        assert!(!r.has_code("SC011"), "{}", r.render_text());
        assert!(!r.has_code("SC012"), "{}", r.render_text());

        // Threshold 0.1 prunes exactly the zero lane of channel 0, so the
        // smallest surviving fan-in is 3 of 4 dense lanes. At k=256 the
        // pruned floor 4·2^8 = 1024 lane-cycles exceeds 3·256 = 768:
        // SC012 reports the ratio and SC011 warns about under-resolution.
        let cfg = base.clone().with_sparsity(SparsityPolicy::threshold(0.1));
        let r = analyze_engine_config(&cfg, &resolved);
        assert!(
            r.at(Severity::Info).any(|d| d.code == "SC012"),
            "{}",
            r.render_text()
        );
        assert!(
            r.at(Severity::Warning).any(|d| d.code == "SC011"),
            "{}",
            r.render_text()
        );
        assert_eq!(r.error_count(), 0, "{}", r.render_text());

        // Raising k past the pruned floor (3·384 = 1152 ≥ 1024) clears the
        // warning while the Info ratio line stays.
        let resolved_384 = PrecisionPlan::uniform(384, 1);
        let cfg = base
            .clone()
            .with_k(384)
            .with_sparsity(SparsityPolicy::threshold(0.1));
        let r = analyze_engine_config(&cfg, &resolved_384);
        assert!(!r.at(Severity::Warning).any(|d| d.code == "SC011"), "{}", r.render_text());
        assert!(r.has_code("SC012"), "{}", r.render_text());

        // An analytic backend owns no k, so the under-resolution warning
        // never applies — only the Info ratio line fires.
        let mut cfg = base.clone().with_sparsity(SparsityPolicy::threshold(0.1));
        cfg.backend = BackendKind::Expectation;
        let r = analyze_engine_config(&cfg, &resolved);
        assert!(!r.has_code("SC011"), "{}", r.render_text());
        assert!(r.has_code("SC012"), "{}", r.render_text());

        // Threshold 0.6 prunes all four lanes of channel 0 (|v| ≤ 0.5):
        // fan-in 0 is an Error — the plan cannot compile.
        let cfg = base.with_sparsity(SparsityPolicy::threshold(0.6));
        let r = analyze_engine_config(&cfg, &resolved);
        assert!(
            r.at(Severity::Error).any(|d| d.code == "SC011"),
            "{}",
            r.render_text()
        );
    }

    #[test]
    fn deployment_lints_sc009_sc010() {
        let t = |rps: f64| Tenant {
            name: "t".into(),
            key: "k".into(),
            rps,
            burst: rps.max(1.0),
        };
        // Queue shallower than the shard count.
        let r = analyze_deployment(4, 2, &[], None);
        assert!(r.has_code("SC010"), "{}", r.render_text());
        // Queue depth 0 means "sum of shard depths" and is fine.
        assert!(!analyze_deployment(4, 0, &[], None).has_code("SC010"));
        // Aggregate quota far above the modeled throughput.
        let net = NetworkSpec::lenet5();
        let est = HardwareEstimate::for_config(
            crate::tech::TechKind::Rfet10,
            8,
            1024,
            &net,
        );
        let capacity = 1e6 / est.metrics.latency_us;
        let r = analyze_deployment(1, 0, &[t(capacity * 10.0)], Some(&est));
        assert!(r.has_code("SC009"), "{}", r.render_text());
        // Under capacity: clean. Unlimited (rps = 0) tenants never count.
        let r = analyze_deployment(1, 0, &[t(capacity * 0.1), t(0.0)], Some(&est));
        assert!(!r.has_code("SC009"), "{}", r.render_text());
    }

    #[test]
    fn invalid_networks_become_sc000_not_panics() {
        let mut net = dense_net(16, 4);
        net.layers.push(LayerSpec::linear(LayerKind::Dense { inputs: 99, outputs: 2 }));
        let r = analyze_network(&net, &PrecisionPlan::uniform(32, 2), 8, None);
        assert!(r.has_code("SC000"), "{}", r.render_text());
        assert!(r.has_errors());
        // A plan that does not fit the network is SC000 too.
        let net = dense_net(16, 4);
        let r = analyze_network(&net, &PrecisionPlan::uniform(0, 1), 8, None);
        assert!(r.has_code("SC000"), "{}", r.render_text());
    }

    #[test]
    fn renderings_carry_codes_fixes_and_valid_json() {
        let net = dense_net(16, 4);
        let f = FaultPlan::new(1).with_stuck_lane(9, 9, true);
        let r = analyze_network(&net, &PrecisionPlan::uniform(32, 1), 8, Some(&f));
        let text = r.render_text();
        assert!(text.contains("warning[SC006]"), "{text}");
        assert!(text.contains("fix:"), "{text}");
        let json = r.render_json();
        // The vendored serve-side parser must accept the analyzer's JSON.
        let parsed = crate::serve::json::parse(&json).expect("analyzer JSON parses");
        match parsed {
            crate::serve::json::Json::Arr(items) => assert!(!items.is_empty()),
            other => panic!("expected an array, got {other:?}"),
        }
        // Errors sort first in the rendered order.
        let worst_first = r.diagnostics();
        for pair in worst_first.windows(2) {
            assert!(pair[0].severity >= pair[1].severity);
        }
    }
}
