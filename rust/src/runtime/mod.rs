//! PJRT runtime: load AOT-compiled HLO text (produced by
//! `python -m compile.aot`) and execute it from the L3 hot path.
//!
//! Follows /opt/xla-example/load_hlo: text (never serialized protos — jax
//! ≥0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects) →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//!
//! `Engine` is deliberately `!Send`-shaped (raw PJRT handles); the
//! coordinator owns each engine on a dedicated worker thread and feeds it
//! through channels.

use anyhow::{Context, Result};
use std::path::Path;

/// A compiled executable on the PJRT CPU client.
pub struct Engine {
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
    /// Source path, for diagnostics.
    pub source: String,
}

impl Engine {
    /// Load and compile an HLO-text artifact.
    pub fn load(path: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Engine { client, exe, source: path.display().to_string() })
    }

    /// Platform name of the underlying client.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute with one f32 input tensor of shape `dims`; returns the flat
    /// f32 output of the (single-element) result tuple.
    pub fn run_f32(&self, input: &[f32], dims: &[i64]) -> Result<Vec<f32>> {
        let lit = xla::Literal::vec1(input).reshape(dims)?;
        let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }

    /// Execute with two u32 input tensors (the sc_mac demo kernel).
    pub fn run_u32_pair(
        &self,
        a: &[u32],
        b: &[u32],
        dims: &[i64],
    ) -> Result<Vec<u32>> {
        let la = xla::Literal::vec1(a).reshape(dims)?;
        let lb = xla::Literal::vec1(b).reshape(dims)?;
        let result = self.exe.execute::<xla::Literal>(&[la, lb])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<u32>()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    /// A tiny hand-written HLO module: f(x) = (x + 1,) over f32[4].
    const ADD_ONE_HLO: &str = r#"HloModule add_one, entry_computation_layout={(f32[4]{0})->(f32[4]{0})}

ENTRY main {
  x = f32[4]{0} parameter(0)
  one = f32[] constant(1)
  ones = f32[4]{0} broadcast(one), dimensions={}
  sum = f32[4]{0} add(x, ones)
  ROOT out = (f32[4]{0}) tuple(sum)
}
"#;

    #[test]
    fn engine_runs_handwritten_hlo() {
        let p = std::env::temp_dir().join(format!("scnn_addone_{}.hlo.txt", std::process::id()));
        std::fs::File::create(&p).unwrap().write_all(ADD_ONE_HLO.as_bytes()).unwrap();
        let engine = Engine::load(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(engine.platform(), "cpu");
        let out = engine.run_f32(&[1.0, 2.0, 3.0, 4.0], &[4]).unwrap();
        assert_eq!(out, vec![2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn missing_artifact_is_an_error() {
        assert!(Engine::load(Path::new("/nonexistent/x.hlo.txt")).is_err());
    }
}
