//! Structural gate-level netlists.
//!
//! This is the substrate that replaces Cadence Genus's RTL→cell mapping for
//! the paper's blocks: every SC component in [`crate::sc`] provides a
//! `build_netlist` that emits one of these structures, and [`crate::sim`]
//! rolls up area / critical path / switching energy over it using a
//! [`crate::tech::CellLibrary`].
//!
//! The paper's blocks are small fixed-structure datapaths (PCCs, counters,
//! adder trees), so hand-constructed structural netlists correspond directly
//! to what synthesis would emit.

use crate::tech::CellKind;
use std::collections::BTreeMap;

/// Identifier of a wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NetId(pub u32);

/// Identifier of a gate instance.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GateId(pub u32);

/// One cell instance.
#[derive(Debug, Clone)]
pub struct Gate {
    /// Which library cell this instantiates.
    pub kind: CellKind,
    /// Input nets, in the order defined by [`CellKind`] docs.
    pub inputs: Vec<NetId>,
    /// Output nets (sum/carry order for adders).
    pub outputs: Vec<NetId>,
}

/// A flat structural netlist.
#[derive(Debug, Clone, Default)]
pub struct Netlist {
    /// Human-readable block name (used in reports).
    pub name: String,
    num_nets: u32,
    gates: Vec<Gate>,
    /// Primary inputs in creation order.
    pub primary_inputs: Vec<NetId>,
    /// Primary outputs in mark order.
    pub primary_outputs: Vec<NetId>,
    /// Nets tied to constants.
    pub constants: Vec<(NetId, bool)>,
}

impl Netlist {
    /// Create an empty netlist.
    pub fn new(name: impl Into<String>) -> Self {
        Netlist { name: name.into(), ..Default::default() }
    }

    fn fresh(&mut self) -> NetId {
        let id = NetId(self.num_nets);
        self.num_nets += 1;
        id
    }

    /// Total number of nets.
    pub fn num_nets(&self) -> usize {
        self.num_nets as usize
    }

    /// All gate instances.
    pub fn gates(&self) -> &[Gate] {
        &self.gates
    }

    /// Allocate a new primary input.
    pub fn input(&mut self) -> NetId {
        let n = self.fresh();
        self.primary_inputs.push(n);
        n
    }

    /// Allocate `n` primary inputs.
    pub fn inputs(&mut self, n: usize) -> Vec<NetId> {
        (0..n).map(|_| self.input()).collect()
    }

    /// A net tied to a constant value.
    pub fn constant(&mut self, value: bool) -> NetId {
        let n = self.fresh();
        self.constants.push((n, value));
        n
    }

    /// Mark `net` as a primary output.
    pub fn mark_output(&mut self, net: NetId) {
        self.primary_outputs.push(net);
    }

    /// Instantiate a gate; returns its output nets.
    pub fn add_gate(&mut self, kind: CellKind, inputs: &[NetId]) -> Vec<NetId> {
        assert_eq!(
            inputs.len(),
            kind.num_inputs(),
            "{kind} expects {} inputs, got {}",
            kind.num_inputs(),
            inputs.len()
        );
        let outputs: Vec<NetId> = (0..kind.num_outputs()).map(|_| self.fresh()).collect();
        self.gates.push(Gate { kind, inputs: inputs.to_vec(), outputs: outputs.clone() });
        outputs
    }

    fn gate1(&mut self, kind: CellKind, inputs: &[NetId]) -> NetId {
        self.add_gate(kind, inputs)[0]
    }

    // ---- single-output conveniences -------------------------------------

    /// Inverter.
    pub fn inv(&mut self, a: NetId) -> NetId {
        self.gate1(CellKind::Inv, &[a])
    }
    /// Buffer.
    pub fn buf(&mut self, a: NetId) -> NetId {
        self.gate1(CellKind::Buf, &[a])
    }
    /// 2-input NAND.
    pub fn nand2(&mut self, a: NetId, b: NetId) -> NetId {
        self.gate1(CellKind::Nand2, &[a, b])
    }
    /// 2-input NOR.
    pub fn nor2(&mut self, a: NetId, b: NetId) -> NetId {
        self.gate1(CellKind::Nor2, &[a, b])
    }
    /// 2-input AND.
    pub fn and2(&mut self, a: NetId, b: NetId) -> NetId {
        self.gate1(CellKind::And2, &[a, b])
    }
    /// 2-input OR.
    pub fn or2(&mut self, a: NetId, b: NetId) -> NetId {
        self.gate1(CellKind::Or2, &[a, b])
    }
    /// 2-input XOR.
    pub fn xor2(&mut self, a: NetId, b: NetId) -> NetId {
        self.gate1(CellKind::Xor2, &[a, b])
    }
    /// 2-input XNOR.
    pub fn xnor2(&mut self, a: NetId, b: NetId) -> NetId {
        self.gate1(CellKind::Xnor2, &[a, b])
    }
    /// 2:1 MUX: output = `sel ? d1 : d0`.
    pub fn mux21(&mut self, d0: NetId, d1: NetId, sel: NetId) -> NetId {
        self.gate1(CellKind::Mux21, &[d0, d1, sel])
    }
    /// D flip-flop; returns Q.
    pub fn dff(&mut self, d: NetId) -> NetId {
        self.gate1(CellKind::Dff, &[d])
    }
    /// RFET reconfigurable gate: `prog = 0` → NAND(a,b), `prog = 1` → NOR(a,b).
    pub fn nandnor(&mut self, a: NetId, b: NetId, prog: NetId) -> NetId {
        self.gate1(CellKind::NandNor, &[a, b, prog])
    }
    /// RFET 3-input XOR.
    pub fn xor3(&mut self, a: NetId, b: NetId, c: NetId) -> NetId {
        self.gate1(CellKind::Xor3, &[a, b, c])
    }
    /// RFET 3-input majority.
    pub fn maj3(&mut self, a: NetId, b: NetId, c: NetId) -> NetId {
        self.gate1(CellKind::Maj3, &[a, b, c])
    }
    /// Half adder; returns (sum, carry).
    pub fn half_adder(&mut self, a: NetId, b: NetId) -> (NetId, NetId) {
        let o = self.add_gate(CellKind::HalfAdder, &[a, b]);
        (o[0], o[1])
    }
    /// Monolithic full-adder cell; returns (sum, carry).
    pub fn full_adder_cell(&mut self, a: NetId, b: NetId, c: NetId) -> (NetId, NetId) {
        let o = self.add_gate(CellKind::FullAdder, &[a, b, c]);
        (o[0], o[1])
    }
    /// RFET compact full adder (Fig. 8c): XOR3 for sum, MAJ3 for carry, plus
    /// two inverters modeling the complementary-signal conditioning the
    /// compact cells require. Returns (sum, carry).
    pub fn full_adder_rfet(&mut self, a: NetId, b: NetId, c: NetId) -> (NetId, NetId) {
        let s = self.xor3(a, b, c);
        let maj = self.maj3(a, b, c);
        // Fig. 8c: "only two reconfigurable gates — XOR3 and MAJ3, along
        // with a few inverters". The inverter pair buffers/conditions the
        // carry output rail.
        let nc = self.inv(maj);
        let carry = self.inv(nc);
        (s, carry)
    }

    /// Per-cell-kind instance counts.
    pub fn cell_counts(&self) -> BTreeMap<CellKind, usize> {
        let mut m = BTreeMap::new();
        for g in &self.gates {
            *m.entry(g.kind).or_insert(0) += 1;
        }
        m
    }

    /// Number of cell instances.
    pub fn num_gates(&self) -> usize {
        self.gates.len()
    }

    /// Total transistor count under `lib` (reporting only).
    pub fn transistors(&self, lib: &crate::tech::CellLibrary) -> u64 {
        self.gates.iter().map(|g| lib.cell(g.kind).transistors as u64).sum()
    }

    /// Fanout (number of reader pins) of every net; primary outputs count as
    /// one load each.
    pub fn fanouts(&self) -> Vec<usize> {
        let mut f = vec![0usize; self.num_nets()];
        for g in &self.gates {
            for &i in &g.inputs {
                f[i.0 as usize] += 1;
            }
        }
        for &o in &self.primary_outputs {
            f[o.0 as usize] += 1;
        }
        f
    }

    /// Reconnect input pin `pin` of gate `gate_idx` to `net`. Used by
    /// builders that must close sequential loops (e.g. LFSR feedback, where
    /// the feedback XOR reads DFF outputs that exist only after the ring is
    /// built).
    pub fn rewire_gate_input(&mut self, gate_idx: usize, pin: usize, net: NetId) {
        let g = &mut self.gates[gate_idx];
        assert!(pin < g.inputs.len(), "pin {pin} out of range for {}", g.kind);
        g.inputs[pin] = net;
    }

    /// Merge another netlist into this one, connecting `other`'s primary
    /// inputs to `bind` (same length). Returns the mapping of `other`'s
    /// primary outputs into this netlist's net space.
    pub fn absorb(&mut self, other: &Netlist, bind: &[NetId]) -> Vec<NetId> {
        assert_eq!(bind.len(), other.primary_inputs.len(), "absorb: input arity mismatch");
        let mut map: Vec<Option<NetId>> = vec![None; other.num_nets()];
        for (k, &pi) in other.primary_inputs.iter().enumerate() {
            map[pi.0 as usize] = Some(bind[k]);
        }
        for &(c, v) in &other.constants {
            let n = self.constant(v);
            map[c.0 as usize] = Some(n);
        }
        // Gates are in creation order; outputs are always fresh nets, so a
        // single pass suffices (inputs either map already or are created by
        // an earlier gate).
        let remap = |m: &mut Vec<Option<NetId>>, slf: &mut Netlist, n: NetId| -> NetId {
            if let Some(x) = m[n.0 as usize] {
                x
            } else {
                let x = slf.fresh();
                m[n.0 as usize] = Some(x);
                x
            }
        };
        for g in &other.gates {
            let ins: Vec<NetId> =
                g.inputs.iter().map(|&n| remap(&mut map, self, n)).collect();
            let outs: Vec<NetId> =
                g.outputs.iter().map(|&n| remap(&mut map, self, n)).collect();
            self.gates.push(Gate { kind: g.kind, inputs: ins, outputs: outs });
        }
        other
            .primary_outputs
            .iter()
            .map(|&n| map[n.0 as usize].expect("output driven"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_simple_and() {
        let mut nl = Netlist::new("and");
        let a = nl.input();
        let b = nl.input();
        let y = nl.and2(a, b);
        nl.mark_output(y);
        assert_eq!(nl.num_gates(), 1);
        assert_eq!(nl.primary_inputs.len(), 2);
        assert_eq!(nl.primary_outputs, vec![y]);
    }

    #[test]
    fn cell_counts_and_fanout() {
        let mut nl = Netlist::new("t");
        let a = nl.input();
        let x = nl.inv(a);
        let y = nl.inv(a);
        let z = nl.and2(x, y);
        nl.mark_output(z);
        let counts = nl.cell_counts();
        assert_eq!(counts[&CellKind::Inv], 2);
        assert_eq!(counts[&CellKind::And2], 1);
        let f = nl.fanouts();
        assert_eq!(f[a.0 as usize], 2);
        assert_eq!(f[z.0 as usize], 1);
    }

    #[test]
    #[should_panic(expected = "expects")]
    fn wrong_arity_panics() {
        let mut nl = Netlist::new("bad");
        let a = nl.input();
        nl.add_gate(CellKind::Nand2, &[a]);
    }

    #[test]
    fn absorb_connects_subcircuit() {
        let mut inner = Netlist::new("inner");
        let a = inner.input();
        let b = inner.input();
        let y = inner.xor2(a, b);
        inner.mark_output(y);

        let mut outer = Netlist::new("outer");
        let p = outer.input();
        let q = outer.input();
        let outs = outer.absorb(&inner, &[p, q]);
        assert_eq!(outs.len(), 1);
        outer.mark_output(outs[0]);
        assert_eq!(outer.num_gates(), 1);
        assert_eq!(outer.gates()[0].inputs, vec![p, q]);
    }

    #[test]
    fn rfet_fa_structure() {
        let mut nl = Netlist::new("fa_rfet");
        let ins = nl.inputs(3);
        let (s, c) = nl.full_adder_rfet(ins[0], ins[1], ins[2]);
        nl.mark_output(s);
        nl.mark_output(c);
        let counts = nl.cell_counts();
        assert_eq!(counts[&CellKind::Xor3], 1);
        assert_eq!(counts[&CellKind::Maj3], 1);
        assert_eq!(counts[&CellKind::Inv], 2);
    }
}
