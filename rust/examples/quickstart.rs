//! Quickstart: the SC datapath end to end on a single neuron, then the
//! same datapath as a whole network behind the `scnn::engine` API.
//!
//! Builds SNGs, generates bipolar bitstreams, multiplies with XNOR, counts
//! with an APC, converts back with B2S/S2B — shows the three PCC flavors
//! side by side — and finally opens an engine `Session` (the one public
//! inference entry point) on a tiny network.
//! Run: `cargo run --release --example quickstart`

use scnn::accel::layers::{LayerKind, LayerSpec, NetworkSpec};
use scnn::accel::network::{LayerWeights, QuantizedWeights};
use scnn::engine::{BackendKind, BatchPolicy, Engine, EngineConfig};
use std::time::Duration;
use scnn::sc::apc::Apc;
use scnn::sc::neuron;
use scnn::sc::pcc::{expected_output, PccKind};
use scnn::sc::sng::Sng;
use scnn::sc::{dequantize_bipolar, quantize_bipolar};

fn main() {
    let bits = 8;
    let k = 256; // bitstream length

    println!("== 1. Encode values as stochastic bitstreams ==");
    let a_val = 0.5f64;
    let w_val = -0.25f64;
    let a_code = quantize_bipolar(a_val, bits);
    let w_code = quantize_bipolar(w_val, bits);
    let mut sng_a = Sng::new(bits, PccKind::Comparator, 17).expect("8-bit SNG");
    let mut sng_w = Sng::new(bits + 3, PccKind::Comparator, 101).expect("11-bit SNG"); // decorrelated RNS
    let a = sng_a.generate(a_code, k);
    let w = sng_w.generate(w_code & ((1 << bits) - 1), k);
    println!("a = {a_val} -> code {a_code} -> stream value {:+.3}", a.value_bipolar());
    println!("w = {w_val} -> code {w_code} -> stream value {:+.3}", w.value_bipolar());

    println!("\n== 2. Multiply with a single XNOR gate (bipolar, Fig. 1b) ==");
    let prod = a.xnor(&w);
    println!(
        "a*w = {:.4} (exact {:+.4}, one gate per product!)",
        prod.value_bipolar(),
        a_val * w_val
    );

    println!("\n== 3. Count products with an APC ==");
    let mut apc = Apc::new(2);
    for t in 0..k {
        apc.step(&[prod.get(t), a.get(t)]);
    }
    println!("APC accumulated {} ones over {k} cycles (2 inputs)", apc.accumulated());

    println!("\n== 4. A full 25-input SC neuron (Frasser style, Fig. 2) ==");
    let n = 25;
    let acodes: Vec<u32> = (0..n).map(|j| quantize_bipolar(0.04 * j as f64, bits)).collect();
    let wcodes: Vec<u32> =
        (0..n).map(|j| quantize_bipolar(if j % 2 == 0 { 0.5 } else { -0.3 }, bits)).collect();
    let acts = sng_a.generate_correlated(&acodes, k);
    let wgts = sng_w.generate_correlated(&wcodes, k);
    let r4: Vec<u32> = {
        let mut l = scnn::sc::Lfsr::new(8, 5).expect("8-bit LFSR");
        (0..k)
            .map(|_| {
                let v = l.value() & 0x3F;
                l.step();
                v
            })
            .collect()
    };
    let out = neuron::forward(&acts, &wgts, &r4, true);
    let pre: f64 = acodes
        .iter()
        .zip(&wcodes)
        .map(|(&ac, &wc)| dequantize_bipolar(ac, bits) * dequantize_bipolar(wc, bits))
        .sum();
    println!(
        "neuron output stream value {:+.3} (expectation {:+.3}, pre-activation {:+.3})",
        out.value_bipolar(),
        neuron::expectation(pre.max(0.0), n, false),
        pre
    );

    println!("\n== 5. The paper's PCC contribution: three converters, same job ==");
    println!("value 0.3 -> code {} ({}-bit)", quantize_bipolar(0.3, bits), bits);
    let x = quantize_bipolar(0.3, bits);
    for kind in PccKind::ALL {
        let mut sng = Sng::new(bits, kind, 99).expect("8-bit SNG");
        let bs = sng.generate(x, 4096);
        println!(
            "  {kind:?}: stream p = {:.4} (ideal {:.4}, closed-form {:.4})",
            bs.value_unipolar(),
            x as f64 / 256.0,
            expected_output(kind, x, bits)
        );
    }
    println!("\nThe RFET NAND-NOR chain (Lemma 1) matches the MUX chain's function");
    println!("with 3-transistor reconfigurable gates — see `cargo bench` for the");
    println!("area/delay/energy comparison (Table I).");

    println!("\n== 6. The same datapath as a network, behind the engine API ==");
    // A tiny 16→4 dense network with synthetic weights: every backend is
    // constructible from one typed EngineConfig.
    let net = NetworkSpec {
        name: "quickstart".into(),
        input: (1, 4, 4),
        layers: vec![LayerSpec {
            kind: LayerKind::Dense { inputs: 16, outputs: 4 },
            relu: false,
        }],
    };
    let codes: Vec<Vec<u32>> = (0..4)
        .map(|oc| {
            (0..16)
                .map(|j| quantize_bipolar(((oc * 5 + j) % 9) as f64 / 4.5 - 1.0, bits))
                .collect()
        })
        .collect();
    let weights = QuantizedWeights {
        bits,
        layers: vec![LayerWeights { codes, gamma: 1.0, mu: 0.0 }],
    };
    let image: Vec<f32> = (0..16).map(|j| j as f32 / 16.0).collect();
    for kind in [
        BackendKind::StochasticFused,
        BackendKind::ReferencePerBit,
        BackendKind::Expectation,
    ] {
        let session = Engine::open(
            EngineConfig::new(kind, net.clone())
                .with_quantized(weights.clone())
                .with_k(k)
                .with_seed(17)
                // Lone blocking requests: don't let the batcher linger, so
                // the printed latency is the datapath, not the batch window.
                .with_batch(BatchPolicy { linger: Duration::ZERO, ..BatchPolicy::default() }),
        )
        .expect("opening session");
        let logits = session.infer(image.clone()).expect("inference");
        let m = session.metrics();
        println!(
            "  {:<18} logits {:?}  ({} request, p50 {} µs)",
            session.backend(),
            logits.iter().map(|v| (v * 100.0).round() / 100.0).collect::<Vec<_>>(),
            m.requests,
            m.latency_percentile_us(50.0)
        );
    }
    println!("  (stochastic-fused and reference-per-bit logits are bit-identical;");
    println!("   expectation is the k→∞ limit of both.)");
}
