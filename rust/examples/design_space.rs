//! Fig. 13 regenerator: channel-count design-space exploration for both
//! technologies — area/latency/energy plus ADP/EDP/EDAP and the optimal
//! channel selection (§V-C finds 8).
//!
//! Run: `cargo run --release --example design_space`

use scnn::accel::layers::NetworkSpec;
use scnn::accel::metrics::argmin_by;
use scnn::accel::system::{self, SystemConfig};
use scnn::benchutil::{gain_pct, print_table};
use scnn::tech::TechKind;

fn main() {
    // Optional positional arg selects any registered topology:
    // `cargo run --release --example design_space -- mnist_strided`.
    let name = std::env::args().nth(1).unwrap_or_else(|| "lenet5".into());
    let net = NetworkSpec::by_name(&name).expect("known network (see NetworkSpec::NAMES)");
    let counts = [1usize, 2, 4, 8, 16, 32];

    for tech in [TechKind::Finfet10, TechKind::Rfet10] {
        let evals = system::sweep_channels(tech, &net, &counts);
        let rows: Vec<Vec<String>> = evals
            .iter()
            .map(|e| {
                let m = &e.metrics;
                vec![
                    e.channels.to_string(),
                    format!("{:.4}", m.area_mm2),
                    format!("{:.2}", m.latency_us),
                    format!("{:.3}", m.energy_uj),
                    format!("{:.4}", m.adp()),
                    format!("{:.4}", m.edp()),
                    format!("{:.5}", m.edap()),
                ]
            })
            .collect();
        print_table(
            &format!("Fig. 13 sweep — {tech} on {}", net.name),
            &["channels", "area mm²", "latency µs", "energy µJ", "ADP", "EDP", "EDAP"],
            &rows,
        );
        let ms: Vec<_> = evals.iter().map(|e| e.metrics).collect();
        println!(
            "optima: ADP -> {} ch, EDP -> {} ch, EDAP -> {} ch (paper: 8)",
            counts[argmin_by(&ms, |m| m.adp())],
            counts[argmin_by(&ms, |m| m.edp())],
            counts[argmin_by(&ms, |m| m.edap())],
        );
        // Area breakdown at the paper's operating point.
        let at8 = &evals[3];
        println!("area breakdown at 8 channels:");
        for (label, um2) in &at8.area_breakdown {
            println!("  {label:<16} {:>10.0} µm²", um2);
        }
    }

    // Head-to-head at the paper's 8-channel configuration (§V-C summary:
    // RFET −5% area, −7.3% delay, −29% energy, EDAP −37.8%).
    let net = NetworkSpec::lenet5();
    let fin = system::evaluate(&SystemConfig::paper(TechKind::Finfet10, 8), &net);
    let rf = system::evaluate(&SystemConfig::paper(TechKind::Rfet10, 8), &net);
    println!("\nRFET vs FinFET at 8 channels (paper: 5% / 7.3% / 29% / 37.8%):");
    println!("  logic area gain : {:+.1}%", gain_pct(fin.channel.area_um2, rf.channel.area_um2));
    println!("  delay gain      : {:+.1}%", gain_pct(fin.metrics.latency_us, rf.metrics.latency_us));
    println!("  energy gain     : {:+.1}%", gain_pct(fin.metrics.energy_uj, rf.metrics.energy_uj));
    println!("  EDAP gain       : {:+.1}%", gain_pct(fin.metrics.edap(), rf.metrics.edap()));
}
