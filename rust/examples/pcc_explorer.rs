//! Fig. 7 regenerator: PCC transfer curves for 3–10-bit CMP / MUX-chain /
//! NAND-NOR converters, plus the Table-I-style hardware cost of each.
//!
//! Run: `cargo run --release --example pcc_explorer [-- --csv]`
//! With `--csv`, emits `results/fig7_transfer.csv`.

use scnn::accel::channel::{characterize_pcc, BITSTREAM_LEN};
use scnn::sc::lfsr::Lfsr;
use scnn::sc::pcc::{self, PccKind};
use scnn::sim;
use scnn::tech::CellLibrary;
use std::io::Write;

fn measure_transfer(kind: PccKind, bits: u32, len: usize) -> Vec<(u32, f64)> {
    // Long-LFSR measurement (matches the paper's simulation setup).
    (0..(1u32 << bits))
        .map(|x| {
            let mut l = Lfsr::new(bits.max(3), 1).expect("supported LFSR width");
            let ones = (0..len)
                .filter(|_| {
                    let r = l.value() & ((1 << bits) - 1);
                    l.step();
                    pcc::pcc_bit(kind, x, r, bits)
                })
                .count();
            (x, ones as f64 / len as f64)
        })
        .collect()
}

fn main() {
    let csv = std::env::args().any(|a| a == "--csv");
    let mut csv_rows = vec!["bits,kind,code,ideal,measured".to_string()];

    println!("Fig. 7 — conversion transfer of the three PCCs (k = 2^16)");
    for bits in 3..=10u32 {
        println!("\n{bits}-bit PCC (showing quartile codes):");
        for kind in PccKind::ALL {
            let curve = measure_transfer(kind, bits, 1 << 16);
            let total = 1u32 << bits;
            let picks: Vec<u32> = vec![0, total / 4, total / 2, 3 * total / 4, total - 1];
            let shown: Vec<String> = picks
                .iter()
                .map(|&x| format!("{:.3}", curve[x as usize].1))
                .collect();
            println!("  {kind:?}: at codes {picks:?} -> {shown:?}");
            // Monotonicity check (what Fig. 7 visually demonstrates).
            let mono = curve.windows(2).all(|w| w[1].1 >= w[0].1 - 0.02);
            assert!(mono, "{kind:?} {bits}-bit transfer not monotone");
            if csv {
                for (x, p) in &curve {
                    csv_rows.push(format!(
                        "{bits},{kind:?},{x},{:.6},{p:.6}",
                        *x as f64 / total as f64
                    ));
                }
            }
        }
    }

    println!("\nHardware cost of the 8-bit PCC (Table I columns):");
    for lib in [CellLibrary::finfet10(), CellLibrary::rfet10()] {
        let rep = characterize_pcc(&lib);
        println!(
            "  {}: {:.2} µm², {:.0} ps, {:.2} fJ/cycle (over {} cycles of stimulus, k={})",
            rep.tech, rep.area_um2, rep.delay_ps, rep.energy_per_cycle_fj, 2048, BITSTREAM_LEN
        );
    }
    // Netlist sizes for every width (the paper's area scaling argument).
    println!("\nGate counts per width (MUX-chain vs NAND-NOR+inverters):");
    for bits in 3..=10u32 {
        let mux = pcc::build_netlist(PccKind::MuxChain, bits);
        let nn = pcc::build_netlist(PccKind::NandNor, bits);
        let lib_f = CellLibrary::finfet10();
        let lib_r = CellLibrary::rfet10();
        println!(
            "  {bits}-bit: MUX {} gates ({:.3} µm² FinFET) | NAND-NOR {} gates ({:.3} µm² RFET)",
            mux.num_gates(),
            sim::area(&mux, &lib_f),
            nn.num_gates(),
            sim::area(&nn, &lib_r),
        );
    }

    if csv {
        std::fs::create_dir_all("results").unwrap();
        let mut f = std::fs::File::create("results/fig7_transfer.csv").unwrap();
        writeln!(f, "{}", csv_rows.join("\n")).unwrap();
        println!("\nwrote results/fig7_transfer.csv ({} rows)", csv_rows.len() - 1);
    }
}
