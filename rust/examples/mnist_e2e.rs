//! END-TO-END driver: proves all three layers compose on a real workload —
//! every inference path through the unified `scnn::engine` API.
//!
//! 1. loads the AOT artifacts (`make artifacts`): the trained LeNet-5
//!    SC-equivalent inference graphs (L2, lowered once from JAX), the
//!    Pallas sc_mac kernel graph (L1), trained weights and the synthetic
//!    test set;
//! 2. streams the full test set through an XLA-backend engine session
//!    (submit/drain with dynamic batching) and reports accuracy / latency /
//!    throughput from the session's own metrics;
//! 3. cross-checks served predictions against sessions on the bit-exact
//!    stochastic backend (LFSR→PCC→XNOR→APC→B2S→ReLU/MP→S2B), the
//!    expectation model, and the noisy-expectation model;
//! 4. executes the L1 Pallas kernel artifact via PJRT and verifies it
//!    bit-for-bit against the Rust packed-bitstream engine.
//!
//! Results are recorded in EXPERIMENTS.md. Run:
//! `make artifacts && cargo run --release --example mnist_e2e`

use anyhow::{bail, Context, Result};
use scnn::accel::layers::NetworkSpec;
use scnn::data::{load_manifest, Artifacts, Dataset, ModelWeights};
use scnn::engine::{classify, BackendKind, BatchPolicy, Engine, EngineConfig};
use scnn::runtime::Engine as PjrtEngine;
use scnn::sc::bitstream::Bitstream;
use std::time::{Duration, Instant};

fn main() -> Result<()> {
    let artifacts = Artifacts::default_dir();
    if !artifacts.present() {
        bail!("artifacts missing — run `make artifacts` first");
    }
    let manifest = load_manifest(&artifacts.manifest())?;
    println!("manifest: {manifest:?}\n");

    let ds = Dataset::load(&artifacts.dataset("digits"))?;
    // One name drives both the topology (registry) and the artifact paths.
    let net = NetworkSpec::by_name("lenet5")?;
    let weights = ModelWeights::load(&artifacts.weights(&net.name, "sc"))?.quantize(8);
    let batch = BatchPolicy {
        max_batch: 32,
        linger: Duration::from_millis(2),
        queue_depth: 256,
    };

    // ---- 2. stream the full test set through the XLA session ----
    let xla = Engine::open(
        EngineConfig::new(BackendKind::Xla, net.clone())
            .with_hlo_ladder(vec![
                (1, artifacts.hlo(&net.name, 1)),
                (8, artifacts.hlo(&net.name, 8)),
                (32, artifacts.hlo(&net.name, 32)),
            ])
            .with_batch(batch),
    )
    .context("opening XLA session")?;
    let t = Instant::now();
    for img in &ds.images {
        xla.submit(img.clone())?;
    }
    let mut preds = Vec::with_capacity(ds.len());
    for (_, res) in xla.drain()? {
        preds.push(classify(&res?));
    }
    let wall = t.elapsed();
    let correct = preds
        .iter()
        .zip(&ds.labels)
        .filter(|(&p, &l)| p == l as usize)
        .count();
    let st = xla.metrics();
    println!("== serving (engine session, XLA backend) ==");
    println!(
        "  {} images in {:.1} ms  ->  {:.0} img/s",
        ds.len(),
        wall.as_secs_f64() * 1e3,
        ds.len() as f64 / wall.as_secs_f64()
    );
    println!(
        "  accuracy {:.2}%  (python-side training accuracy: {})",
        100.0 * correct as f64 / ds.len() as f64,
        manifest.get("acc_lenet5_sc").map(String::as_str).unwrap_or("?")
    );
    println!(
        "  latency p50 {} µs  p99 {} µs  mean batch {:.1}",
        st.latency_percentile_us(50.0),
        st.latency_percentile_us(99.0),
        st.mean_batch()
    );

    // ---- 2b. the same test set through the bit-exact SC session ----
    let n_serve = 64.min(ds.len());
    let sc = Engine::open(
        EngineConfig::new(BackendKind::StochasticFused, net.clone())
            .with_quantized(weights.clone())
            .with_k(32)
            .with_seed(7)
            .with_batch(batch),
    )
    .context("opening SC session")?;
    let t = Instant::now();
    for img in &ds.images[..n_serve] {
        sc.submit(img.clone())?;
    }
    let mut sc_preds = Vec::with_capacity(n_serve);
    for (_, res) in sc.drain()? {
        sc_preds.push(classify(&res?));
    }
    let sc_wall = t.elapsed();
    let sc_m = sc.metrics();
    let sc_correct = sc_preds
        .iter()
        .zip(&ds.labels[..n_serve])
        .filter(|(&p, &l)| p == l as usize)
        .count();
    println!("\n== serving (engine session, bit-exact SC backend, k=32) ==");
    println!(
        "  {} images in {:.1} ms  ->  {:.0} img/s  (mean batch {:.1})",
        n_serve,
        sc_wall.as_secs_f64() * 1e3,
        n_serve as f64 / sc_wall.as_secs_f64(),
        sc_m.mean_batch()
    );
    println!(
        "  accuracy {:.2}% ({sc_correct}/{n_serve}) at the k=32 noise floor",
        100.0 * sc_correct as f64 / n_serve as f64
    );
    if let Some(est) = sc_m.estimate {
        println!(
            "  modeled hardware: {} ×{}ch — {:.3} µJ/inference, {:.2} µs",
            est.tech, est.channels, est.metrics.energy_uj, est.metrics.latency_us
        );
    }

    // ---- 3. cross-check the analytic and stochastic backends ----
    let n_check = 40.min(ds.len());
    let sample = &ds.images[..n_check];
    let mk = |kind: BackendKind, k: usize, seed: u32| {
        Engine::open(
            EngineConfig::new(kind, net.clone())
                .with_quantized(weights.clone())
                .with_k(k)
                .with_seed(seed)
                .with_batch(batch),
        )
    };
    let exp_session = mk(BackendKind::Expectation, 32, 1)?;
    let sc_session = mk(BackendKind::StochasticFused, 32, 1)?;
    let noisy_session = mk(BackendKind::NoisyExpectation, 4096, 1)?;
    let t = Instant::now();
    let exp_outs = exp_session.infer_batch(sample)?;
    let sc_outs = sc_session.infer_batch(sample)?;
    let noisy_outs = noisy_session.infer_batch(sample)?;
    // Batched and single-image paths must be bit-identical.
    let single = sc_session.infer(sample[0].clone())?;
    if single != sc_outs[0] {
        bail!("session infer_batch diverged from single-image infer");
    }
    let mut agree_exp = 0;
    let mut agree_sc = 0;
    let mut agree_noisy = 0;
    for i in 0..n_check {
        agree_exp += (classify(&exp_outs[i]) == preds[i]) as usize;
        agree_sc += (classify(&sc_outs[i]) == ds.labels[i] as usize) as usize;
        agree_noisy += (classify(&noisy_outs[i]) == ds.labels[i] as usize) as usize;
    }
    println!("\n== bit-exact stochastic datapath (8-bit) ==");
    println!(
        "  expectation model vs served graph: {agree_exp}/{n_check} agree ({:.0}%)",
        100.0 * agree_exp as f64 / n_check as f64
    );
    println!(
        "  SC-noise model accuracy at k=4096: {agree_noisy}/{n_check} ({:.0}%)",
        100.0 * agree_noisy as f64 / n_check as f64
    );
    println!(
        "  full LFSR→PCC→XNOR→APC→B2S→S2B sim at k=32: {agree_sc}/{n_check} ({:.0}%), {:.2} s",
        100.0 * agree_sc as f64 / n_check as f64,
        t.elapsed().as_secs_f64()
    );
    println!(
        "  (k=32 sits below this network's SC noise floor — the training\n            is not yet noise-aware; see EXPERIMENTS.md Fig. 11 notes.)"
    );
    if agree_exp * 10 < n_check * 9 {
        bail!("expectation model diverged from the served graph");
    }
    if agree_noisy * 10 < n_check * 8 {
        bail!("SC-noise model should classify well at k=4096");
    }

    // ---- 4. L1 Pallas kernel vs the Rust bitstream engine ----
    let kernel = PjrtEngine::load(&artifacts.dir.join("sc_mac_demo.hlo.txt"))?;
    let (neurons, fan_in, words) = (128usize, 25usize, 1usize);
    let mut rng = scnn::sc::rng::XorShift64::new(0x5EED);
    let mut step = move || rng.next_u32();
    let a: Vec<u32> = (0..neurons * fan_in * words).map(|_| step()).collect();
    let w: Vec<u32> = (0..neurons * fan_in * words).map(|_| step()).collect();
    let counts = kernel.run_u32_pair(&a, &w, &[neurons as i64, fan_in as i64, words as i64])?;
    let mut mismatches = 0;
    for n in 0..neurons {
        let mut expected = 0u32;
        for j in 0..fan_in {
            let idx = n * fan_in + j;
            let sa = Bitstream::from_fn(32, |t| (a[idx] >> t) & 1 == 1);
            let sw = Bitstream::from_fn(32, |t| (w[idx] >> t) & 1 == 1);
            expected += sa.xnor(&sw).count_ones();
        }
        if counts[n] != expected {
            mismatches += 1;
        }
    }
    println!("\n== L1 Pallas sc_mac kernel (PJRT) vs Rust bitstream engine ==");
    println!("  {neurons} neurons × {fan_in} products × 32 cycles: {mismatches} mismatches");
    if mismatches > 0 {
        bail!("kernel/engine mismatch");
    }
    println!("\nE2E OK: all three layers compose.");
    Ok(())
}
