//! Cross-backend parity property tests for the `engine::Session` API:
//! every backend, constructed through an `EngineConfig` alone, must agree
//! with the `ReferencePerBit` golden model on the same seeded inputs —
//! bit-exactly for the fused SC engine, within a sampling-noise tolerance
//! for the analytic and XLA backends.

use scnn::accel::layers::{Conv2d, LayerKind, LayerSpec, NetworkSpec};
use scnn::accel::network::{LayerWeights, QuantizedWeights};
use scnn::engine::{BackendKind, Engine, EngineConfig, Precision, Session};
use scnn::sc::{dequantize_bipolar, quantize_bipolar};
use std::io::Write;
use std::path::PathBuf;

/// Seeded xorshift so case generation is deterministic (proptest is not
/// vendored; same convention as `tests/prop.rs`).
struct Gen(u64);

impl Gen {
    fn next(&mut self) -> u64 {
        self.0 ^= self.0 << 13;
        self.0 ^= self.0 >> 7;
        self.0 ^= self.0 << 17;
        self.0
    }

    /// Uniform in [-1, 1).
    fn f64(&mut self) -> f64 {
        (self.next() % 2000) as f64 / 1000.0 - 1.0
    }
}

/// A conv→pool→dense network exercising padding, ReLU, pooling, and the
/// final affine — the same shape the network-level golden tests use.
fn conv_net() -> NetworkSpec {
    NetworkSpec {
        name: "parity-conv".into(),
        input: (1, 6, 6),
        layers: vec![
            LayerSpec::active(LayerKind::conv(1, 2, 3, 1)),
            LayerSpec::linear(LayerKind::MaxPool { size: 2 }),
            LayerSpec::linear(LayerKind::Dense { inputs: 18, outputs: 3 }),
        ],
    }
}

/// The extended vocabulary under the session API: strided conv, depthwise
/// conv, SC scaled-add residual, average pool, global average pool.
fn extended_net() -> NetworkSpec {
    NetworkSpec {
        name: "parity-extended".into(),
        input: (1, 8, 8),
        layers: vec![
            LayerSpec::active(LayerKind::Conv(Conv2d::square(1, 4, 3, 1).with_stride(2, 2))),
            LayerSpec::active(LayerKind::Conv(Conv2d::square(4, 4, 3, 1).depthwise())),
            LayerSpec::linear(LayerKind::Add { from: 0 }),
            LayerSpec::linear(LayerKind::AvgPool { size: 2 }),
            LayerSpec::linear(LayerKind::GlobalAvgPool),
            LayerSpec::linear(LayerKind::Dense { inputs: 4, outputs: 3 }),
        ],
    }
}

fn extended_weights(bits: u32, seed: u64) -> QuantizedWeights {
    let mut w = QuantizedWeights::synthetic(&extended_net(), bits, seed.max(1)).unwrap();
    for (i, l) in w.layers.iter_mut().enumerate() {
        l.gamma = 0.4 + 0.1 * i as f64;
        l.mu = 0.9;
    }
    w
}

fn extended_image(seed: u64) -> Vec<f32> {
    let mut g = Gen(seed.max(1) ^ 0xEE77);
    (0..64).map(|_| (g.next() % 1000) as f32 / 1000.0).collect()
}

fn conv_weights(bits: u32, seed: u64) -> QuantizedWeights {
    let mut g = Gen(seed.max(1));
    let l0: Vec<Vec<u32>> =
        (0..2).map(|_| (0..9).map(|_| quantize_bipolar(g.f64() * 0.5, bits)).collect()).collect();
    let l1: Vec<Vec<u32>> =
        (0..3).map(|_| (0..18).map(|_| quantize_bipolar(g.f64() * 0.9, bits)).collect()).collect();
    QuantizedWeights {
        bits,
        layers: vec![
            LayerWeights { codes: l0, gamma: 0.35, mu: 0.9 },
            LayerWeights { codes: l1, gamma: 1.0, mu: 1.2 },
        ],
    }
}

fn conv_image(seed: u64) -> Vec<f32> {
    let mut g = Gen(seed.max(1) ^ 0xABCD);
    (0..36).map(|_| (g.next() % 1000) as f32 / 1000.0).collect()
}

fn open(cfg: EngineConfig) -> Session {
    Engine::open(cfg).expect("opening session")
}

fn sc_cfg(kind: BackendKind, k: usize, seed: u32, wseed: u64) -> EngineConfig {
    EngineConfig::new(kind, conv_net())
        .with_quantized(conv_weights(8, wseed))
        .with_k(k)
        .with_seed(seed)
}

#[test]
fn fused_backend_is_bit_exact_vs_reference_per_bit() {
    // Bitstream lengths below, at, and across the 64-bit packing boundary.
    for k in [16usize, 64, 104] {
        for seed in [3u32, 7] {
            let fused = open(sc_cfg(BackendKind::StochasticFused, k, seed, 42));
            let golden = open(sc_cfg(BackendKind::ReferencePerBit, k, seed, 42));
            let images: Vec<Vec<f32>> = (0..4).map(|i| conv_image(i as u64 + 1)).collect();
            let a = fused.infer_batch(&images).unwrap();
            let b = golden.infer_batch(&images).unwrap();
            assert_eq!(a, b, "k={k} seed={seed}");
        }
    }
}

#[test]
fn extended_ops_fused_backend_is_bit_exact_vs_reference() {
    // Strided conv, depthwise conv, residual add, avg/global pooling: the
    // fused and per-bit backends lower the same stage IR through the
    // session API and must agree bit-for-bit.
    let mk = |kind: BackendKind, k: usize, seed: u32| {
        open(
            EngineConfig::new(kind, extended_net())
                .with_quantized(extended_weights(8, 19))
                .with_k(k)
                .with_seed(seed),
        )
    };
    for k in [32usize, 104] {
        for seed in [2u32, 9] {
            let fused = mk(BackendKind::StochasticFused, k, seed);
            let golden = mk(BackendKind::ReferencePerBit, k, seed);
            let images: Vec<Vec<f32>> = (0..3).map(|i| extended_image(i as u64 + 1)).collect();
            assert_eq!(
                fused.infer_batch(&images).unwrap(),
                golden.infer_batch(&images).unwrap(),
                "k={k} seed={seed}"
            );
        }
    }
}

#[test]
fn per_layer_precision_sessions_are_bit_exact_vs_reference() {
    // The session-level face of the PrecisionPlan refactor: a per-layer
    // policy with different adjacent ks, fused vs per-bit reference,
    // bit-for-bit through the typed config alone — on the extended
    // vocabulary (strided, depthwise, residual, pooling).
    let mk = |kind: BackendKind, ks: Vec<usize>| {
        open(
            EngineConfig::new(kind, extended_net())
                .with_quantized(extended_weights(8, 19))
                .with_precision(Precision::PerLayer(ks))
                .with_seed(6),
        )
    };
    // extended_net has three compute stages (two convs + the dense head).
    for ks in [vec![64usize, 32, 96], vec![16, 104, 64]] {
        let fused = mk(BackendKind::StochasticFused, ks.clone());
        let golden = mk(BackendKind::ReferencePerBit, ks.clone());
        assert_eq!(
            fused.precision().map(|p| p.ks().to_vec()),
            Some(ks.clone()),
            "the session reports the plan it executes"
        );
        let images: Vec<Vec<f32>> = (0..3).map(|i| extended_image(i as u64 + 1)).collect();
        assert_eq!(
            fused.infer_batch(&images).unwrap(),
            golden.infer_batch(&images).unwrap(),
            "ks={ks:?}"
        );
    }
    // Uniform(k) through the policy surface is bit-exact with the legacy
    // scalar with_k path (they are the same resolved plan).
    let legacy = open(
        EngineConfig::new(BackendKind::StochasticFused, extended_net())
            .with_quantized(extended_weights(8, 19))
            .with_k(64)
            .with_seed(6),
    );
    let policy = mk(BackendKind::StochasticFused, vec![64, 64, 64]);
    let img = extended_image(9);
    assert_eq!(legacy.infer(img.clone()).unwrap(), policy.infer(img).unwrap());
}

#[test]
fn degenerate_precision_errors_at_open_instead_of_reaching_kernels() {
    let mk = |p: Precision| {
        Engine::open(
            EngineConfig::new(BackendKind::StochasticFused, extended_net())
                .with_quantized(extended_weights(8, 19))
                .with_precision(p),
        )
    };
    let err = mk(Precision::Uniform(0)).unwrap_err().to_string();
    assert!(err.contains("invalid precision policy"), "{err}");
    let err = mk(Precision::Uniform(100)).unwrap_err().to_string();
    assert!(err.contains("multiple"), "{err}");
    let err = mk(Precision::PerLayer(vec![64, 64])).unwrap_err().to_string();
    assert!(err.contains("compute layers"), "{err}");
    assert!(mk(Precision::Auto { accuracy_budget: 1.2 }).is_err());
}

#[test]
fn extended_ops_expectation_tracks_reference_within_tolerance() {
    // Logits live in the sp domain of the final dense layer (fan-in 4 ⇒
    // scale 8); at k=4096 the sampling noise is well under 1.0 mean-abs.
    let exp = open(
        EngineConfig::new(BackendKind::Expectation, extended_net())
            .with_quantized(extended_weights(8, 7)),
    );
    let golden = open(
        EngineConfig::new(BackendKind::ReferencePerBit, extended_net())
            .with_quantized(extended_weights(8, 7))
            .with_k(4096)
            .with_seed(3),
    );
    let mut total = 0.0f64;
    let mut count = 0usize;
    for i in 0..3u64 {
        let img = extended_image(40 + i);
        let e = exp.infer(img.clone()).unwrap();
        let r = golden.infer(img).unwrap();
        total += e.iter().zip(&r).map(|(a, b)| (a - b).abs() as f64).sum::<f64>();
        count += e.len();
    }
    let mean_abs = total / count as f64;
    assert!(mean_abs < 1.0, "mean |expectation - reference| = {mean_abs}");
}

#[test]
fn invalid_topologies_error_at_open_instead_of_panicking() {
    // The maxpool silent-truncation bug, surfaced through Engine::open.
    let bad = NetworkSpec {
        name: "bad-pool".into(),
        input: (1, 7, 7),
        layers: vec![
            LayerSpec::active(LayerKind::conv(1, 2, 1, 0)),
            LayerSpec::linear(LayerKind::MaxPool { size: 2 }),
        ],
    };
    let cfg = EngineConfig::new(BackendKind::Expectation, bad)
        .with_quantized(conv_weights(8, 1));
    let err = match Engine::open(cfg) {
        Ok(_) => panic!("opening a truncating-pool network must fail"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains("does not divide"), "{err}");
}

#[test]
fn expectation_backend_tracks_reference_within_tolerance() {
    // At k=4096 the stochastic sampling noise on these logits (sp domain,
    // scale 2^m ≈ 32 for fan-in 18) is well under 2.0 mean-absolute.
    for wseed in [11u64, 29] {
        let exp = open(sc_cfg(BackendKind::Expectation, 32, 1, wseed));
        let golden = open(sc_cfg(BackendKind::ReferencePerBit, 4096, 3, wseed));
        let mut total = 0.0f64;
        let mut count = 0usize;
        for i in 0..3u64 {
            let img = conv_image(100 + i);
            let e = exp.infer(img.clone()).unwrap();
            let r = golden.infer(img).unwrap();
            assert_eq!(e.len(), r.len());
            total += e.iter().zip(&r).map(|(a, b)| (a - b).abs() as f64).sum::<f64>();
            count += e.len();
        }
        let mean_abs = total / count as f64;
        assert!(mean_abs < 2.0, "wseed={wseed}: mean |expectation - reference| = {mean_abs}");
    }
}

#[test]
fn noisy_and_fixed_backends_construct_and_stay_in_range() {
    // NoisyExpectation converges on Expectation as k grows; FixedPoint is
    // a different model (hard ReLU) but must produce finite logits of the
    // right arity from the same config surface.
    let exp = open(sc_cfg(BackendKind::Expectation, 32, 1, 5));
    let noisy = open(sc_cfg(BackendKind::NoisyExpectation, 1 << 16, 9, 5));
    let fixed = open(sc_cfg(BackendKind::FixedPoint, 32, 1, 5));
    for i in 0..3u64 {
        let img = conv_image(i + 7);
        let e = exp.infer(img.clone()).unwrap();
        let n = noisy.infer(img.clone()).unwrap();
        let f = fixed.infer(img).unwrap();
        assert_eq!(e.len(), 3);
        assert_eq!(f.len(), 3);
        assert!(f.iter().all(|v| v.is_finite()));
        let mean_abs: f64 =
            e.iter().zip(&n).map(|(a, b)| (a - b).abs() as f64).sum::<f64>() / e.len() as f64;
        assert!(mean_abs < 0.5, "image {i}: noisy(k=65536) drifted {mean_abs} from expectation");
    }
}

#[test]
fn batched_and_single_session_paths_are_bit_identical() {
    for kind in [
        BackendKind::StochasticFused,
        BackendKind::ReferencePerBit,
        BackendKind::Expectation,
        BackendKind::NoisyExpectation,
        BackendKind::FixedPoint,
    ] {
        let session = open(sc_cfg(kind, 64, 5, 13));
        let images: Vec<Vec<f32>> = (0..5).map(|i| conv_image(50 + i as u64)).collect();
        let batch = session.infer_batch(&images).unwrap();
        for (i, img) in images.iter().enumerate() {
            let single = session.infer(img.clone()).unwrap();
            assert_eq!(batch[i], single, "{kind} image {i}");
        }
        let m = session.metrics();
        assert_eq!(m.requests, 10, "{kind}: 5 batched + 5 single");
        assert!(m.estimate.is_some(), "{kind} models SC hardware");
    }
}

// ---- XLA parity on a linear network -------------------------------------
//
// The XLA backend runs an AOT graph, so parity is checked on a network
// whose SC expectation is exactly linear algebra: one Dense layer, no
// ReLU, gamma=1, mu=0, every weight row constant. With inputs and weights
// chosen on the 8-bit quantization grid, the expectation logits equal the
// HLO graph's f32 arithmetic exactly, and the per-bit reference agrees to
// stochastic sampling noise at large k.

const CLASSES: usize = 10;

/// Weight value per class, on the 8-bit bipolar grid (code 40 + 16c).
fn xla_weight(c: usize) -> f64 {
    dequantize_bipolar(40 + 16 * c as u32, 8)
}

fn linear_net() -> NetworkSpec {
    NetworkSpec {
        name: "parity-linear".into(),
        input: (1, 2, 2),
        layers: vec![LayerSpec {
            kind: LayerKind::Dense { inputs: 4, outputs: CLASSES },
            relu: false,
        }],
    }
}

fn linear_weights() -> QuantizedWeights {
    let codes: Vec<Vec<u32>> =
        (0..CLASSES).map(|c| vec![quantize_bipolar(xla_weight(c), 8); 4]).collect();
    QuantizedWeights { bits: 8, layers: vec![LayerWeights { codes, gamma: 1.0, mu: 0.0 }] }
}

/// out[b, c] = sum(x[b]) * w[c] — the linear net above as HLO text.
fn linear_hlo(batch: usize) -> String {
    let w: Vec<String> = (0..CLASSES).map(|c| format!("{}", xla_weight(c))).collect();
    format!(
        r#"HloModule parity_b{batch}, entry_computation_layout={{(f32[{batch},1,2,2]{{3,2,1,0}})->(f32[{batch},{CLASSES}]{{1,0}})}}

add {{
  a = f32[] parameter(0)
  b = f32[] parameter(1)
  ROOT s = f32[] add(a, b)
}}

ENTRY main {{
  x = f32[{batch},1,2,2]{{3,2,1,0}} parameter(0)
  xr = f32[{batch},4]{{1,0}} reshape(x)
  w = f32[{CLASSES}]{{0}} constant({{{wlist}}})
  zero = f32[] constant(0)
  sums = f32[{batch}]{{0}} reduce(xr, zero), dimensions={{1}}, to_apply=add
  sb = f32[{batch},{CLASSES}]{{1,0}} broadcast(sums), dimensions={{0}}
  wb = f32[{batch},{CLASSES}]{{1,0}} broadcast(w), dimensions={{1}}
  prod = f32[{batch},{CLASSES}]{{1,0}} multiply(sb, wb)
  ROOT out = (f32[{batch},{CLASSES}]{{1,0}}) tuple(prod)
}}
"#,
        wlist = w.join(",")
    )
}

fn write_tmp(name: &str, text: &str) -> PathBuf {
    let p = std::env::temp_dir()
        .join(format!("scnn_parity_{name}_{}.hlo.txt", std::process::id()));
    std::fs::File::create(&p).unwrap().write_all(text.as_bytes()).unwrap();
    p
}

/// Images whose pixels sit exactly on the 8-bit bipolar grid.
fn grid_image(seed: u64) -> Vec<f32> {
    let mut g = Gen(seed.max(1) ^ 0x5EED);
    (0..4).map(|_| dequantize_bipolar(128 + (g.next() % 128) as u32, 8) as f32).collect()
}

#[test]
fn xla_backend_agrees_with_expectation_and_reference() {
    let p1 = write_tmp("b1", &linear_hlo(1));
    let p4 = write_tmp("b4", &linear_hlo(4));
    let xla = open(
        EngineConfig::new(BackendKind::Xla, linear_net())
            .with_hlo_ladder(vec![(1, p1.clone()), (4, p4.clone())]),
    );
    let exp = open(
        EngineConfig::new(BackendKind::Expectation, linear_net())
            .with_quantized(linear_weights()),
    );
    let golden = open(
        EngineConfig::new(BackendKind::ReferencePerBit, linear_net())
            .with_quantized(linear_weights())
            .with_k(4096)
            .with_seed(3),
    );
    let images: Vec<Vec<f32>> = (0..6).map(|i| grid_image(i as u64 + 1)).collect();
    let x = xla.infer_batch(&images).unwrap();
    let e = exp.infer_batch(&images).unwrap();
    let r = golden.infer_batch(&images).unwrap();
    for i in 0..images.len() {
        assert_eq!(x[i].len(), CLASSES);
        for c in 0..CLASSES {
            // On-grid inputs: the SC expectation *is* the graph's f32 math.
            assert!(
                (x[i][c] - e[i][c]).abs() < 1e-4,
                "image {i} class {c}: xla {} vs expectation {}",
                x[i][c],
                e[i][c]
            );
            // The per-bit reference agrees to sampling noise (k=4096,
            // fan-in 4 ⇒ sp scale 8; 6σ comfortably under 1.2).
            assert!(
                (x[i][c] as f64 - r[i][c] as f64).abs() < 1.2,
                "image {i} class {c}: xla {} vs reference {}",
                x[i][c],
                r[i][c]
            );
        }
    }
    drop(xla);
    std::fs::remove_file(p1).ok();
    std::fs::remove_file(p4).ok();
}

#[test]
fn every_backend_constructs_from_config_alone() {
    // The api contract of the redesign: a plain EngineConfig is sufficient
    // to open each of the four backend families.
    for kind in [
        BackendKind::StochasticFused,
        BackendKind::ReferencePerBit,
        BackendKind::Expectation,
    ] {
        let session = open(sc_cfg(kind, 32, 1, 3));
        assert_eq!(session.backend(), kind.label());
        assert_eq!(session.in_len(), 36);
        assert_eq!(session.out_len(), 3);
    }
    let p1 = write_tmp("ctor_b1", &linear_hlo(1));
    let xla = open(
        EngineConfig::new(BackendKind::Xla, linear_net())
            .with_hlo_ladder(vec![(1, p1.clone())]),
    );
    assert_eq!(xla.backend(), "xla");
    assert_eq!(xla.in_len(), 4);
    assert_eq!(xla.out_len(), CLASSES);
    drop(xla);
    std::fs::remove_file(p1).ok();
}
