//! Integration tests for `engine::EnginePool`: cross-shard bit-exactness,
//! admission-control load shedding, hash-affinity routing, worker-death
//! recovery, graceful close, and shared-plan reuse — the serving contract
//! of ISSUE 4's acceptance criteria.

use scnn::accel::layers::{LayerKind, LayerSpec, NetworkSpec};
use scnn::accel::network::{LayerWeights, QuantizedWeights};
use scnn::engine::{
    backend, BackendKind, BatchPolicy, Engine, EngineConfig, EngineError, EnginePool, Placement,
    PoolConfig,
};
use scnn::sc::quantize_bipolar;
use std::sync::Arc;
use std::time::Duration;

fn tiny_net() -> NetworkSpec {
    NetworkSpec {
        name: "pool-tiny".into(),
        input: (1, 4, 4),
        layers: vec![LayerSpec {
            kind: LayerKind::Dense { inputs: 16, outputs: 4 },
            relu: false,
        }],
    }
}

fn tiny_weights() -> QuantizedWeights {
    let codes: Vec<Vec<u32>> = (0..4)
        .map(|oc| {
            (0..16)
                .map(|j| quantize_bipolar(((oc * 3 + j) % 13) as f64 / 6.5 - 1.0, 8))
                .collect()
        })
        .collect();
    QuantizedWeights { bits: 8, layers: vec![LayerWeights { codes, gamma: 1.0, mu: 0.0 }] }
}

fn fused_cfg(k: usize) -> EngineConfig {
    EngineConfig::new(BackendKind::StochasticFused, tiny_net())
        .with_quantized(tiny_weights())
        .with_k(k)
        .with_batch(BatchPolicy { linger: Duration::from_millis(1), ..BatchPolicy::default() })
}

fn images(n: usize) -> Vec<Vec<f32>> {
    (0..n).map(|i| (0..16).map(|j| ((i * 5 + j) % 11) as f32 / 11.0).collect()).collect()
}

#[test]
fn multi_shard_pool_is_bit_identical_to_single_session() {
    let imgs = images(16);
    let single = Engine::open(fused_cfg(64)).unwrap();
    let expected = single.infer_batch(&imgs).unwrap();

    for shards in [2usize, 3] {
        let pool = EnginePool::open(PoolConfig::replicated(fused_cfg(64), shards)).unwrap();
        assert_eq!(pool.shards(), shards);
        assert_eq!(pool.healthy_shards(), shards);
        // The closed-loop batch path (fans across every shard).
        let batch = pool.infer_batch(&imgs).unwrap();
        assert_eq!(batch, expected, "{shards}-shard batch is bit-identical");
        // The routed single-request path.
        for (i, img) in imgs.iter().enumerate() {
            assert_eq!(
                pool.infer(img.clone()).unwrap(),
                expected[i],
                "{shards}-shard infer image {i}"
            );
        }
        // The streaming path, in submission order.
        let mut tickets = Vec::new();
        for img in &imgs {
            tickets.push(pool.submit(img.clone()).unwrap());
        }
        assert_eq!(pool.outstanding(), imgs.len());
        let drained = pool.drain().unwrap();
        assert_eq!(pool.outstanding(), 0);
        for (i, (ticket, res)) in drained.iter().enumerate() {
            assert_eq!(*ticket, tickets[i], "pool submission order preserved");
            assert_eq!(ticket.seq() as usize, i);
            assert_eq!(res.as_ref().unwrap(), &expected[i], "streamed image {i}");
        }
        let m = pool.metrics();
        assert_eq!(m.shards, shards);
        assert_eq!(m.requests, 3 * imgs.len());
        assert_eq!(m.failed, 0);
        assert_eq!(m.shed, 0);
    }
}

#[test]
fn homogeneous_shards_share_one_compiled_plan() {
    // A unique k isolates this test's cache line from the others.
    let cfg = fused_cfg(72);
    let p1 = backend::shared_plan(&cfg).unwrap();
    assert_eq!(Arc::strong_count(&p1), 1);
    let pool = EnginePool::open(PoolConfig::replicated(cfg.clone(), 4)).unwrap();
    // One handle here + one per shard, all pointing at a single compile:
    // the strong count is exact, unlike the global compile counter, which
    // sibling tests bump concurrently.
    assert_eq!(Arc::strong_count(&p1), 5, "4 shards share one compiled plan");
    let p2 = backend::shared_plan(&cfg).unwrap();
    assert!(Arc::ptr_eq(&p1, &p2));
    assert!(backend::plan_compile_count() >= 1);
    drop(pool);
}

#[test]
fn per_layer_precision_pool_is_bit_identical_and_shares_one_plan() {
    // Shards under a per-layer precision policy resolve to ONE compiled
    // plan per artifact fingerprint (the plan's ks are part of the key)
    // and stay bit-identical to a single session on the same plan. 88 is
    // a unique k for cache-line isolation, like the test above.
    let cfg = fused_cfg(64).with_precision(scnn::engine::Precision::PerLayer(vec![88]));
    let p1 = backend::shared_plan(&cfg).unwrap();
    assert_eq!(p1.precision().ks(), &[88]);
    let single = Engine::open(cfg.clone()).unwrap();
    let pool = EnginePool::open(PoolConfig::replicated(cfg.clone(), 3)).unwrap();
    assert_eq!(
        Arc::strong_count(&p1),
        5,
        "1 handle + 1 single session + 3 shards share one compiled plan"
    );
    let imgs = images(12);
    assert_eq!(
        pool.infer_batch(&imgs).unwrap(),
        single.infer_batch(&imgs).unwrap(),
        "per-layer pool output is bit-identical to a single session"
    );
    // A different per-layer assignment is a different artifact.
    let other =
        fused_cfg(64).with_precision(scnn::engine::Precision::PerLayer(vec![96]));
    let p_other = backend::shared_plan(&other).unwrap();
    assert!(!Arc::ptr_eq(&p1, &p_other));
}

#[test]
fn full_admission_queue_sheds_with_typed_rejected() {
    let pool = EnginePool::open(
        PoolConfig::replicated(fused_cfg(32), 1).with_queue_depth(4),
    )
    .unwrap();
    let imgs = images(10);
    let mut accepted = 0;
    let mut rejections = Vec::new();
    for img in &imgs {
        match pool.submit(img.clone()) {
            Ok(_) => accepted += 1,
            Err(e) => rejections.push(e),
        }
    }
    assert_eq!(accepted, 4, "exactly the admission depth is accepted");
    assert_eq!(rejections.len(), 6);
    for e in &rejections {
        match e {
            EngineError::Rejected { retry_after_hint } => {
                assert!(*retry_after_hint >= Duration::from_micros(100));
            }
            other => panic!("expected Rejected, got {other:?}"),
        }
    }
    let m = pool.metrics();
    assert_eq!(m.shed, 6);
    // Incremental drain frees exactly one admission slot: one more
    // submission is admitted, the next is shed again.
    let (t0, r0) = pool.drain_one().unwrap();
    assert_eq!(t0.seq(), 0, "drain_one pops the oldest submission");
    assert!(r0.is_ok());
    pool.submit(imgs[4].clone()).unwrap();
    assert!(matches!(pool.submit(imgs[5].clone()), Err(EngineError::Rejected { .. })));
    // A full drain frees the rest; the pool accepts again.
    let drained = pool.drain().unwrap();
    assert_eq!(drained.len(), 4);
    assert!(drained.iter().all(|(_, r)| r.is_ok()));
    pool.submit(imgs[0].clone()).unwrap();
    let after = pool.drain().unwrap();
    assert_eq!(after.len(), 1);
}

#[test]
fn full_shard_queues_shed_instead_of_blocking_submit() {
    let mut cfg = fused_cfg(32);
    // One backpressure slot per shard, held open by a long linger.
    cfg.batch = BatchPolicy {
        max_batch: 8,
        linger: Duration::from_millis(300),
        queue_depth: 1,
    };
    // Generous global admission so the shed below is the per-shard path.
    let pool = EnginePool::open(PoolConfig::replicated(cfg, 2).with_queue_depth(64)).unwrap();
    let imgs = images(3);
    pool.submit(imgs[0].clone()).unwrap();
    pool.submit(imgs[1].clone()).unwrap();
    // Both shard queues are full: the pool must shed typed, never park.
    match pool.submit(imgs[2].clone()) {
        Err(EngineError::Rejected { retry_after_hint }) => {
            assert!(retry_after_hint >= Duration::from_micros(100));
        }
        other => panic!("expected Rejected when every shard queue is full, got {other:?}"),
    }
    assert_eq!(pool.metrics().shed, 1);
    let drained = pool.drain().unwrap();
    assert_eq!(drained.len(), 2);
    assert!(drained.iter().all(|(_, r)| r.is_ok()));
    // Queues drained: the pool accepts again.
    pool.submit(imgs[2].clone()).unwrap();
    assert_eq!(pool.drain().unwrap().len(), 1);
}

#[test]
fn hash_affinity_is_stable_and_serves_keyed_requests() {
    let pool = EnginePool::open(
        PoolConfig::replicated(fused_cfg(32), 4).with_placement(Placement::HashKey),
    )
    .unwrap();
    let keys: Vec<String> = (0..16).map(|i| format!("client-{i}")).collect();
    let routed: Vec<usize> = keys.iter().map(|k| pool.shard_for_key(k).unwrap()).collect();
    // Stability: the same key maps to the same shard, call after call.
    for _ in 0..50 {
        for (k, &expect) in keys.iter().zip(&routed) {
            assert_eq!(pool.shard_for_key(k).unwrap(), expect, "key {k}");
        }
    }
    // Spread: 16 keys over 4 shards hit more than one shard.
    let distinct: std::collections::HashSet<usize> = routed.iter().copied().collect();
    assert!(distinct.len() > 1, "keys spread over shards: {routed:?}");
    // Keyed inference matches unkeyed results bit-for-bit (same plan).
    let single = Engine::open(fused_cfg(32)).unwrap();
    for (i, img) in images(8).into_iter().enumerate() {
        let expected = single.infer(img.clone()).unwrap();
        let got = pool.infer_keyed(&keys[i], img).unwrap();
        assert_eq!(got, expected, "keyed image {i}");
    }
}

#[test]
fn injected_shard_death_reroutes_without_panicking() {
    let imgs = images(12);
    let single = Engine::open(fused_cfg(64)).unwrap();
    let expected = single.infer_batch(&imgs).unwrap();

    let pool = EnginePool::open(PoolConfig::replicated(fused_cfg(64), 2)).unwrap();
    // Warm both shards, then kill shard 1 out from under the router.
    pool.infer(imgs[0].clone()).unwrap();
    pool.shard_session(1).unwrap().close();
    // Every request still succeeds, bit-identical, via rerouting.
    for (i, img) in imgs.iter().enumerate() {
        assert_eq!(pool.infer(img.clone()).unwrap(), expected[i], "image {i}");
    }
    let m = pool.metrics();
    assert_eq!(m.healthy, 1, "the dead shard is marked unhealthy");
    assert!(m.rerouted >= 1, "its traffic was rerouted");
    // The batch path also survives with one shard down.
    assert_eq!(pool.infer_batch(&imgs).unwrap(), expected);
    // Kill the survivor: requests now fail typed, never hang or panic.
    pool.shard_session(0).unwrap().close();
    match pool.infer(imgs[0].clone()) {
        Err(EngineError::NoHealthyShards) => {}
        other => panic!("expected NoHealthyShards, got {other:?}"),
    }
    assert_eq!(pool.healthy_shards(), 0);
}

#[test]
fn heterogeneous_shards_serve_behind_one_front_door() {
    // A fused shard and an expectation shard: same net, same shapes,
    // different datapaths — the router serves from both.
    let shards = vec![fused_cfg(32), {
        EngineConfig::new(BackendKind::Expectation, tiny_net()).with_quantized(tiny_weights())
    }];
    let pool = EnginePool::open(PoolConfig::heterogeneous(shards)).unwrap();
    assert_eq!(pool.shards(), 2);
    for img in images(6) {
        let out = pool.infer(img).unwrap();
        assert_eq!(out.len(), 4);
    }
    let m = pool.metrics();
    assert_eq!(m.requests, 6);
    assert!(m.backend.contains("stochastic-fused") && m.backend.contains("expectation"));
}

#[test]
fn graceful_close_drains_and_refuses_typed() {
    let pool = EnginePool::open(PoolConfig::replicated(fused_cfg(32), 2)).unwrap();
    let imgs = images(8);
    let mut tickets = Vec::new();
    for img in &imgs {
        tickets.push(pool.submit(img.clone()).unwrap());
    }
    pool.close();
    assert!(pool.is_closed());
    // New work is refused typed on every front door.
    match pool.submit(imgs[0].clone()) {
        Err(EngineError::Closed) => {}
        other => panic!("expected Closed, got {other:?}"),
    }
    match pool.infer(imgs[0].clone()) {
        Err(EngineError::Closed) => {}
        other => panic!("expected Closed, got {other:?}"),
    }
    match pool.infer_batch(&imgs) {
        Err(EngineError::Closed) => {}
        other => panic!("expected Closed, got {other:?}"),
    }
    // Work queued before the close was executed and is still drainable.
    let drained = pool.drain().unwrap();
    assert_eq!(drained.len(), 8);
    for (i, (ticket, res)) in drained.iter().enumerate() {
        assert_eq!(*ticket, tickets[i]);
        assert!(res.is_ok(), "queued request {i} served across close: {res:?}");
    }
    // A drained, closed pool reports the empty queue typed.
    match pool.drain() {
        Err(EngineError::EmptyQueue) => {}
        other => panic!("expected EmptyQueue, got {other:?}"),
    }
}
