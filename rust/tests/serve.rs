//! Loopback integration tests for the `scnn::serve` front door: HTTP
//! inference bit-identical to a direct `Session`, typed 4xx rejects for
//! oversized/malformed traffic, tenant quotas with `Retry-After`,
//! ticket-ordered concurrent batches, a parseable Prometheus exposition,
//! and the regression guard for admission backoff running in connection
//! workers rather than the accept path.

use scnn::accel::layers::{LayerKind, LayerSpec, NetworkSpec};
use scnn::accel::network::{LayerWeights, QuantizedWeights};
use scnn::engine::{BackendKind, Engine, EngineConfig, EnginePool, Placement, PoolConfig};
use scnn::sc::quantize_bipolar;
use scnn::serve::json::{self, Json};
use scnn::serve::{read_response, ServeConfig, Server, TenantRegistry};
use std::io::Write;
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn tiny_net() -> NetworkSpec {
    NetworkSpec {
        name: "serve-tiny".into(),
        input: (1, 4, 4),
        layers: vec![LayerSpec {
            kind: LayerKind::Dense { inputs: 16, outputs: 3 },
            relu: false,
        }],
    }
}

fn tiny_weights() -> QuantizedWeights {
    let codes: Vec<Vec<u32>> = (0..3)
        .map(|oc| {
            (0..16)
                .map(|j| quantize_bipolar(((oc * 5 + j) % 9) as f64 / 4.5 - 1.0, 8))
                .collect()
        })
        .collect();
    QuantizedWeights { bits: 8, layers: vec![LayerWeights { codes, gamma: 1.0, mu: 0.0 }] }
}

fn engine_cfg() -> EngineConfig {
    EngineConfig::new(BackendKind::Expectation, tiny_net()).with_quantized(tiny_weights())
}

fn images(n: usize) -> Vec<Vec<f32>> {
    (0..n).map(|i| (0..16).map(|j| ((i * 7 + j) % 11) as f32 / 11.0).collect()).collect()
}

/// Opens a pool and a server on an ephemeral loopback port.
fn start(
    pool_cfg: PoolConfig,
    registry: TenantRegistry,
    scfg: ServeConfig,
) -> (Server, Arc<EnginePool>, String) {
    let pool = Arc::new(EnginePool::open(pool_cfg).unwrap());
    let server = Server::start(Arc::clone(&pool), registry, "127.0.0.1:0", scfg).unwrap();
    let addr = server.local_addr().to_string();
    (server, pool, addr)
}

/// One raw request on a fresh connection; returns status, headers, body.
fn send_raw(addr: &str, raw: &[u8]) -> (u16, Vec<(String, String)>, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    stream.write_all(raw).unwrap();
    let (status, headers, body) = read_response(&mut stream).unwrap();
    (status, headers, String::from_utf8_lossy(&body).into_owned())
}

fn post(
    addr: &str,
    path: &str,
    body: &str,
    extra: &[(&str, &str)],
) -> (u16, Vec<(String, String)>, String) {
    let mut req = format!("POST {path} HTTP/1.1\r\nHost: t\r\n");
    req.push_str(&format!("Content-Length: {}\r\n", body.len()));
    for (k, v) in extra {
        req.push_str(&format!("{k}: {v}\r\n"));
    }
    req.push_str("\r\n");
    req.push_str(body);
    send_raw(addr, req.as_bytes())
}

fn get(addr: &str, path: &str) -> (u16, Vec<(String, String)>, String) {
    send_raw(addr, format!("GET {path} HTTP/1.1\r\nHost: t\r\n\r\n").as_bytes())
}

fn header<'a>(headers: &'a [(String, String)], name: &str) -> Option<&'a str> {
    headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
}

fn image_body(img: &[f32]) -> String {
    format!("{{\"image\":{}}}", json::render_f32s(img))
}

#[test]
fn infer_over_http_is_bit_identical_to_a_direct_session() {
    let pc = PoolConfig::replicated(engine_cfg(), 2);
    let (_server, _pool, addr) = start(pc, TenantRegistry::open(), ServeConfig::default());
    let single = Engine::open(engine_cfg()).unwrap();
    for (i, img) in images(6).into_iter().enumerate() {
        let expected = single.infer(img.clone()).unwrap();
        let (status, _, resp) = post(&addr, "/v1/infer", &image_body(&img), &[]);
        assert_eq!(status, 200, "image {i}: {resp}");
        let doc = json::parse(&resp).unwrap();
        let output = doc.get("output").unwrap().as_f32_vec().unwrap();
        assert_eq!(output, expected, "image {i} is bit-identical over HTTP");
        let class = match doc.get("class") {
            Some(Json::Num(n)) => *n as usize,
            other => panic!("bad class field: {other:?}"),
        };
        assert_eq!(class, scnn::engine::classify(&expected), "image {i} argmax");
    }
    // A bare top-level array is accepted too.
    let img = images(1).remove(0);
    let (status, _, resp) = post(&addr, "/v1/infer", &json::render_f32s(&img), &[]);
    assert_eq!(status, 200);
    let doc = json::parse(&resp).unwrap();
    let output = doc.get("output").unwrap().as_f32_vec().unwrap();
    assert_eq!(output, single.infer(img).unwrap(), "bare-array body");
}

#[test]
fn oversized_bodies_get_413_and_the_server_survives() {
    let scfg = ServeConfig { max_body: 256, ..ServeConfig::default() };
    let pc = PoolConfig::replicated(engine_cfg(), 1);
    let (_server, _pool, addr) = start(pc, TenantRegistry::open(), scfg);
    let huge = "x".repeat(4096);
    let (status, _, resp) = post(&addr, "/v1/infer", &huge, &[]);
    assert_eq!(status, 413, "declared 4096 > max 256: {resp}");
    // The reject is typed and the listener is still serving.
    let img = images(1).remove(0);
    let (status, _, _) = post(&addr, "/v1/infer", &image_body(&img), &[]);
    assert_eq!(status, 200, "server healthy after an oversized body");
}

#[test]
fn malformed_traffic_gets_typed_4xx_and_never_kills_the_server() {
    let pc = PoolConfig::replicated(engine_cfg(), 1);
    let (_server, pool, addr) = start(pc, TenantRegistry::open(), ServeConfig::default());
    // Garbage request line.
    let (status, _, resp) = send_raw(&addr, b"NOT AN HTTP REQUEST\r\n\r\n");
    assert_eq!(status, 400, "garbage request line: {resp}");
    // Colon-less header.
    let (status, _, _) = send_raw(&addr, b"GET /healthz HTTP/1.1\r\nHost t\r\n\r\n");
    assert_eq!(status, 400, "colon-less header");
    // Body that is not JSON.
    let (status, _, resp) = post(&addr, "/v1/infer", "{not json", &[]);
    assert_eq!(status, 400, "malformed JSON");
    assert!(resp.contains("bad_request"), "typed reject body: {resp}");
    // Wrong element type inside the image array.
    let (status, _, _) = post(&addr, "/v1/infer", "{\"image\":[1,\"two\"]}", &[]);
    assert_eq!(status, 400, "non-numeric image element");
    // Wrong method and unknown path are typed, not panics.
    let (status, _, _) = get(&addr, "/v1/infer");
    assert_eq!(status, 405, "GET on a POST endpoint");
    let (status, _, _) = get(&addr, "/nope");
    assert_eq!(status, 404, "unknown endpoint");
    // After all of that the pool is untouched and healthz is green.
    let (status, _, body) = get(&addr, "/healthz");
    assert_eq!(status, 200, "healthz after abuse: {body}");
    assert!(body.contains("\"status\":\"ok\""), "healthz body: {body}");
    assert_eq!(pool.healthy_shards(), 1);
}

#[test]
fn quota_exhaustion_returns_429_with_retry_after() {
    // 0.5 tokens/s with burst 1: the second request must wait ~2 s.
    let registry = TenantRegistry::parse("slow:key-slow:0.5:1").unwrap();
    let pc = PoolConfig::replicated(engine_cfg(), 1);
    let (_server, pool, addr) = start(pc, registry, ServeConfig::default());
    let img = images(1).remove(0);
    let body = image_body(&img);
    let auth = [("X-Api-Key", "key-slow")];
    // No key at all: 401, not 429.
    let (status, _, resp) = post(&addr, "/v1/infer", &body, &[]);
    assert_eq!(status, 401, "tenanted server requires a key: {resp}");
    let (status, _, _) = post(&addr, "/v1/infer", &body, &[("X-Api-Key", "wrong")]);
    assert_eq!(status, 401, "unknown key");
    // First keyed request drains the burst.
    let (status, _, resp) = post(&addr, "/v1/infer", &body, &auth);
    assert_eq!(status, 200, "first request within burst: {resp}");
    // Second is over quota: 429 with a ceil'd Retry-After.
    let (status, headers, resp) = post(&addr, "/v1/infer", &body, &auth);
    assert_eq!(status, 429, "second request over quota: {resp}");
    assert_eq!(header(&headers, "retry-after"), Some("2"), "ceil(1/0.5) seconds");
    assert!(resp.contains("quota"), "typed quota body: {resp}");
    // The Bearer form authenticates the same tenant.
    let bearer = [("Authorization", "Bearer key-slow")];
    let (status, _, _) = post(&addr, "/v1/infer", &body, &bearer);
    assert_eq!(status, 429, "same bucket via Authorization: Bearer");
    // The rejects are on the tenant's ledger, not the pool's shed count.
    let m = pool.metrics();
    let t = m.tenants.iter().find(|t| t.tenant == "slow").unwrap();
    assert_eq!(t.requests, 1);
    assert_eq!(t.quota_rejected, 2);
    assert_eq!(m.shed, 0, "quota rejects never reach the pool");
}

#[test]
fn concurrent_tenant_batches_come_back_in_submission_order() {
    let spec = "alpha:key-a:10000:10000;beta:key-b:10000:10000";
    let registry = TenantRegistry::parse(spec).unwrap();
    let pc = PoolConfig::replicated(engine_cfg(), 2).with_placement(Placement::HashKey);
    let (_server, _pool, addr) = start(pc, registry, ServeConfig::default());
    let single = Engine::open(engine_cfg()).unwrap();
    let jobs = [("key-a", images(12)), ("key-b", images(9))];
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for (key, imgs) in &jobs {
            let addr = addr.as_str();
            handles.push(scope.spawn(move || {
                let mut body = String::from("{\"images\":[");
                for (i, img) in imgs.iter().enumerate() {
                    if i > 0 {
                        body.push(',');
                    }
                    body.push_str(&json::render_f32s(img));
                }
                body.push_str("]}");
                post(addr, "/v1/batch", &body, &[("X-Api-Key", *key)])
            }));
        }
        for (handle, (_, imgs)) in handles.into_iter().zip(&jobs) {
            let (status, _, resp) = handle.join().unwrap();
            assert_eq!(status, 200, "batch: {resp}");
            let doc = json::parse(&resp).unwrap();
            let results = match doc.get("results") {
                Some(Json::Arr(items)) => items,
                other => panic!("bad results field: {other:?}"),
            };
            assert_eq!(results.len(), imgs.len());
            let expected = single.infer_batch(imgs).unwrap();
            for (i, item) in results.iter().enumerate() {
                let got = item.as_f32_vec().unwrap();
                assert_eq!(got, expected[i], "result {i} in submission order, bit-exact");
            }
        }
    });
}

/// Minimal Prometheus text-format check: every line is a comment or a
/// `name{labels} value` sample whose value parses as a float.
fn assert_prometheus_parses(text: &str) -> usize {
    let mut samples = 0;
    for line in text.lines() {
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (name_part, value) = line.rsplit_once(' ').unwrap_or_else(|| {
            panic!("sample line has no value: {line:?}");
        });
        assert!(value.parse::<f64>().is_ok(), "unparseable value in {line:?}");
        let metric = match name_part.split_once('{') {
            Some((metric, labels)) => {
                assert!(labels.ends_with('}'), "unterminated labels in {line:?}");
                metric
            }
            None => name_part,
        };
        assert!(!metric.is_empty(), "empty metric name in {line:?}");
        let ok = metric
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':');
        assert!(ok, "bad metric name {metric:?}");
        samples += 1;
    }
    samples
}

#[test]
fn metrics_expose_parseable_prometheus_with_tenant_counters() {
    let registry = TenantRegistry::parse("alpha:key-a:1000:1000").unwrap();
    let pc = PoolConfig::replicated(engine_cfg(), 2);
    let (_server, _pool, addr) = start(pc, registry, ServeConfig::default());
    let img = images(1).remove(0);
    for _ in 0..3 {
        let (status, _, _) =
            post(&addr, "/v1/infer", &image_body(&img), &[("X-Api-Key", "key-a")]);
        assert_eq!(status, 200);
    }
    let (status, headers, text) = get(&addr, "/metrics");
    assert_eq!(status, 200);
    let ctype = header(&headers, "content-type").unwrap();
    assert!(ctype.starts_with("text/plain"), "exposition content type: {ctype}");
    let samples = assert_prometheus_parses(&text);
    assert!(samples > 10, "a real exposition has many samples, got {samples}");
    for family in [
        "scnn_pool_shards 2",
        "scnn_requests_total 3",
        "scnn_request_latency_microseconds_count 3",
        "scnn_tenant_requests_total{tenant=\"alpha\"} 3",
        "scnn_http_connections_total",
        "scnn_http_responses_total{code=\"200\"}",
    ] {
        assert!(text.contains(family), "missing {family:?} in exposition:\n{text}");
    }
}

/// Regression test for the accept-path backoff bug: admission-reject
/// backoff must run in the connection worker that owns the throttled
/// request, so an unrelated client connecting at the same time is served
/// immediately instead of queueing behind another tenant's retry sleeps.
#[test]
fn shed_backoff_stalls_only_the_throttled_connection() {
    let spec = "alpha:key-a:100000:100000;beta:key-b:100000:100000";
    let registry = TenantRegistry::parse(spec).unwrap();
    // One shard, one admission slot, 20 ms per inference: a batch of 8
    // spends most of its wall-clock retrying shed submits.
    let ecfg = engine_cfg().with_chaos_slow(Duration::from_millis(20));
    let pc = PoolConfig::replicated(ecfg, 1).with_queue_depth(1);
    let scfg =
        ServeConfig { batch_retry_budget: Duration::from_secs(20), ..ServeConfig::default() };
    let (_server, _pool, addr) = start(pc, registry, scfg);

    let imgs = images(8);
    let done = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let batch_done = Arc::clone(&done);
    let batch_addr = addr.clone();
    let batch = std::thread::spawn(move || {
        let mut body = String::from("{\"images\":[");
        for (i, img) in imgs.iter().enumerate() {
            if i > 0 {
                body.push(',');
            }
            body.push_str(&json::render_f32s(img));
        }
        body.push_str("]}");
        let out = post(&batch_addr, "/v1/batch", &body, &[("X-Api-Key", "key-a")]);
        batch_done.store(true, std::sync::atomic::Ordering::Release);
        out
    });
    // While the batch is backing off in its own worker, a second tenant
    // keeps getting served promptly. The bound is loose (threads, CI) but
    // far below the batch's multi-hundred-ms retry phase.
    let mut probes = 0;
    while !done.load(std::sync::atomic::Ordering::Acquire) && probes < 200 {
        let t = Instant::now();
        let (status, _, _) = get(&addr, "/healthz");
        assert_eq!(status, 200);
        assert!(
            t.elapsed() < Duration::from_secs(2),
            "healthz stalled behind another tenant's backoff"
        );
        probes += 1;
        std::thread::sleep(Duration::from_millis(2));
    }
    assert!(probes > 0, "probed at least once while the batch ran");
    let (status, _, resp) = batch.join().unwrap();
    assert_eq!(status, 200, "throttled batch eventually completes: {resp}");
    let doc = json::parse(&resp).unwrap();
    match doc.get("count") {
        Some(Json::Num(n)) => assert_eq!(*n as usize, 8),
        other => panic!("bad count field: {other:?}"),
    }
}

#[test]
fn graceful_shutdown_drains_and_refuses_new_connections() {
    let pc = PoolConfig::replicated(engine_cfg(), 1);
    let (server, pool, addr) = start(pc, TenantRegistry::open(), ServeConfig::default());
    let img = images(1).remove(0);
    let (status, _, _) = post(&addr, "/v1/infer", &image_body(&img), &[]);
    assert_eq!(status, 200);
    server.shutdown();
    server.shutdown(); // idempotent
    assert!(pool.is_closed(), "shutdown closes the pool");
    // The listener is gone: a fresh connection either fails to connect
    // or is never answered.
    match TcpStream::connect(&addr) {
        Err(_) => {}
        Ok(mut stream) => {
            stream.set_read_timeout(Some(Duration::from_millis(500))).unwrap();
            let _ = stream.write_all(b"GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n");
            assert!(read_response(&mut stream).is_err(), "no one serves after shutdown");
        }
    }
}
