//! Property-based tests (proptest-lite: the proptest crate is not vendored
//! offline, so properties run over seeded pseudo-random case generators —
//! same invariants, deterministic replay via the printed seed).

use scnn::accel::metrics::SystemMetrics;
use scnn::sc::apc::{approximate_count, decode_output, Apc};
use scnn::sc::bitstream::{Bitstream, VerticalCounter};
use scnn::sc::pcc::{expected_output, pcc_bit, PccKind};
use scnn::sc::rng::XorShift64;
use scnn::sc::{dequantize_bipolar, quantize_bipolar};

struct Gen(XorShift64);
impl Gen {
    fn new(seed: u64) -> Self {
        Gen(XorShift64::new(seed))
    }
    fn next(&mut self) -> u64 {
        self.0.next_u64()
    }
    fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.next() % (hi - lo)
    }
    fn f64(&mut self) -> f64 {
        (self.next() >> 11) as f64 / (1u64 << 53) as f64
    }
}

/// Run a property over `n` seeded cases; failures print the case seed.
fn prop(name: &str, n: usize, mut f: impl FnMut(&mut Gen)) {
    for case in 0..n {
        let seed = 0x5EED_0000 + case as u64;
        let mut g = Gen::new(seed);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut g)));
        if let Err(e) = r {
            panic!("property {name} failed at case seed {seed:#x}: {e:?}");
        }
    }
}

#[test]
fn prop_quantize_roundtrip_error_bounded() {
    prop("quantize", 500, |g| {
        let bits = g.range(2, 12) as u32;
        let v = g.f64() * 2.0 - 1.0;
        let q = dequantize_bipolar(quantize_bipolar(v, bits), bits);
        // One LSB of rounding, two near the top-of-range cap (code 2^b−1).
        assert!((q - v).abs() <= 2.0 / (1u64 << bits) as f64 + 1e-12, "bits={bits} v={v} q={q}");
    });
}

#[test]
fn prop_bitstream_ops_preserve_length_and_counts() {
    prop("bitstream", 300, |g| {
        let len = g.range(1, 400) as usize;
        let a = Bitstream::from_fn(len, |_| g.next() % 2 == 1);
        let b = Bitstream::from_fn(len, |_| g.next() % 3 == 0);
        // De Morgan on packed streams incl. tail masking.
        let lhs = a.and(&b).not();
        let rhs = a.not().or(&b.not());
        assert_eq!(lhs, rhs);
        // XNOR = NOT XOR.
        assert_eq!(a.xnor(&b), a.xor(&b).not());
        // Counts bounded by length.
        assert!(a.count_ones() as usize <= len);
    });
}

#[test]
fn prop_from_fn_words_equals_from_fn() {
    // Word-at-a-time construction ≡ per-bit construction, on random
    // lengths crossing word boundaries.
    prop("from_fn_words", 300, |g| {
        let len = g.range(1, 400) as usize;
        let bits: Vec<bool> = (0..len).map(|_| g.next() % 2 == 1).collect();
        let per_bit = Bitstream::from_fn(len, |t| bits[t]);
        let by_words = Bitstream::from_fn_words(len, |w| {
            let mut word = 0u64;
            for (i, &bit) in bits.iter().skip(w * 64).take(64).enumerate() {
                word |= (bit as u64) << i;
            }
            // Garbage above the tail must be masked off by the constructor.
            let valid = (len - w * 64).min(64);
            let mask = if valid == 64 { !0u64 } else { (1u64 << valid) - 1 };
            word | !mask
        });
        assert_eq!(per_bit, by_words, "len={len}");
        let mut refilled = Bitstream::zeros(7);
        refilled.fill_from_fn_words(len, |w| {
            let mut word = 0u64;
            for (i, &bit) in bits.iter().skip(w * 64).take(64).enumerate() {
                word |= (bit as u64) << i;
            }
            word
        });
        assert_eq!(per_bit, refilled, "len={len}");
    });
}

#[test]
fn prop_inplace_ops_equal_allocating_ops() {
    prop("inplace", 300, |g| {
        let len = g.range(1, 400) as usize;
        let a = Bitstream::from_fn(len, |_| g.next() % 2 == 1);
        let b = Bitstream::from_fn(len, |_| g.next() % 3 == 0);
        // Output starts as junk of a random unrelated length.
        let junk = g.range(0, 100) as usize;
        let mut out = Bitstream::ones(junk);
        a.xnor_into(&b, &mut out);
        assert_eq!(out, a.xnor(&b));
        a.and_into(&b, &mut out);
        assert_eq!(out, a.and(&b));
        a.or_into(&b, &mut out);
        assert_eq!(out, a.or(&b));
        a.xor_into(&b, &mut out);
        assert_eq!(out, a.xor(&b));
        a.not_into(&mut out);
        assert_eq!(out, a.not());
    });
}

#[test]
fn prop_fused_accumulate_equals_composed() {
    // add_xnor ≡ add(xnor) and add3 ≡ add;add;add, across word boundaries.
    prop("fused_accumulate", 150, |g| {
        let len = g.range(1, 300) as usize;
        let n = g.range(3, 30) as usize;
        let pairs: Vec<(Bitstream, Bitstream)> = (0..n)
            .map(|_| {
                (
                    Bitstream::from_fn(len, |_| g.next() % 2 == 1),
                    Bitstream::from_fn(len, |_| g.next() % 3 == 0),
                )
            })
            .collect();
        let mut fused = VerticalCounter::new(len, n);
        let mut composed = VerticalCounter::new(len, n);
        for (a, b) in &pairs {
            fused.add_xnor(a, b);
            composed.add(&a.xnor(b));
        }
        let t = g.range(0, len as u64) as usize;
        assert_eq!(fused.count_at(t), composed.count_at(t));
        assert_eq!(fused.total(), composed.total());

        let streams: Vec<Bitstream> =
            (0..n).map(|_| Bitstream::from_fn(len, |_| g.next() % 2 == 1)).collect();
        let mut by3 = VerticalCounter::new(len, n);
        let mut one = VerticalCounter::new(len, n);
        let mut it = streams.chunks_exact(3);
        for tri in &mut it {
            by3.add3(&tri[0], &tri[1], &tri[2]);
        }
        for s in it.remainder() {
            by3.add(s);
        }
        for s in &streams {
            one.add(s);
        }
        assert_eq!(by3.added(), one.added());
        assert_eq!(by3.count_at(t), one.count_at(t));
        assert_eq!(by3.total(), one.total());
    });
}

#[test]
fn prop_b2s_ones_equals_streamed_pipeline() {
    // The fused B2S→ReLU→S2B popcount ≡ building the streams explicitly.
    prop("b2s_ones", 100, |g| {
        let len = g.range(1, 300) as usize;
        let n = g.range(1, 30) as usize;
        let mut vc = VerticalCounter::new(len, n);
        for _ in 0..n {
            vc.add(&Bitstream::from_fn(len, |_| g.next() % 2 == 1));
        }
        let m1 = usize::BITS - n.leading_zeros() + 1;
        let r4: Vec<u32> = (0..len).map(|_| (g.next() % (1u64 << m1)) as u32).collect();
        let b2s = Bitstream::from_fn(len, |t| 2 * vc.count_at(t) > r4[t]);
        assert_eq!(vc.b2s_ones(&r4, 0), b2s.count_ones());
        let relu_zero = Bitstream::from_fn(len, |t| n as u32 > r4[t]);
        assert_eq!(vc.b2s_ones(&r4, n as u32), b2s.or(&relu_zero).count_ones());
    });
}

#[test]
fn prop_vertical_counter_equals_naive() {
    prop("vcounter", 100, |g| {
        let len = g.range(1, 200) as usize;
        let n = g.range(1, 40) as usize;
        let streams: Vec<Bitstream> =
            (0..n).map(|_| Bitstream::from_fn(len, |_| g.next() % 2 == 1)).collect();
        let mut vc = VerticalCounter::new(len, n);
        for s in &streams {
            vc.add(s);
        }
        let t = g.range(0, len as u64) as usize;
        let naive: u32 = streams.iter().map(|s| s.get(t) as u32).sum();
        assert_eq!(vc.count_at(t), naive);
    });
}

#[test]
fn prop_pcc_expectation_within_lsb_of_ideal() {
    prop("pcc", 200, |g| {
        let bits = g.range(3, 11) as u32;
        let x = g.range(0, 1 << bits) as u32;
        for kind in PccKind::ALL {
            let m = expected_output(kind, x, bits);
            let ideal = x as f64 / (1u64 << bits) as f64;
            assert!(
                (m - ideal).abs() <= 1.6 / (1u64 << bits) as f64 + 1e-12,
                "{kind:?} bits={bits} x={x} m={m}"
            );
        }
    });
}

#[test]
fn prop_pcc_bit_matches_expectation_over_exhaustive_r() {
    prop("pcc_exhaustive", 40, |g| {
        let bits = g.range(3, 8) as u32;
        let x = g.range(0, 1 << bits) as u32;
        for kind in PccKind::ALL {
            let total = 1u64 << bits;
            let ones =
                (0..total).filter(|&r| pcc_bit(kind, x, r as u32, bits)).count() as f64;
            let m = expected_output(kind, x, bits);
            assert!((ones / total as f64 - m).abs() < 1e-9, "{kind:?}");
        }
    });
}

#[test]
fn prop_apc_accumulation_linear() {
    prop("apc", 100, |g| {
        let n = g.range(1, 30) as usize;
        let cycles = g.range(1, 50) as usize;
        let mut apc = Apc::new(n);
        let mut total = 0u64;
        for _ in 0..cycles {
            let bits: Vec<bool> = (0..n).map(|_| g.next() % 2 == 1).collect();
            total += bits.iter().filter(|&&b| b).count() as u64;
            apc.step(&bits);
        }
        assert_eq!(apc.accumulated(), total);
        // The approximate counter never exceeds the exact count.
        let bits: Vec<bool> = (0..n).map(|_| g.next() % 2 == 1).collect();
        let exact = bits.iter().filter(|&&b| b).count() as u32;
        assert!(approximate_count(&bits) <= exact);
    });
}

#[test]
fn prop_decode_output_inverts_bit_order() {
    prop("decode", 200, |g| {
        let v = g.range(0, 1 << 16);
        let bits: Vec<bool> = (0..16).map(|i| (v >> i) & 1 == 1).collect();
        assert_eq!(decode_output(&bits), v);
    });
}

#[test]
fn prop_metrics_products_scale() {
    prop("metrics", 200, |g| {
        let m = SystemMetrics {
            channels: 1,
            area_mm2: 0.1 + g.f64(),
            logic_area_mm2: 0.01 + g.f64() * 0.1,
            latency_us: 0.1 + g.f64() * 10.0,
            energy_uj: 0.1 + g.f64(),
            power_mw: 1.0 + g.f64() * 100.0,
            clock_ghz: 1.0,
            tops: 0.1 + g.f64(),
        };
        // EDAP = EDP × logic area; ADP/latency = logic area.
        assert!((m.edap() - m.edp() * m.logic_area_mm2).abs() < 1e-12);
        assert!((m.adp() / m.latency_us - m.logic_area_mm2).abs() < 1e-12);
        assert!(m.tops_per_watt() > 0.0);
    });
}

#[test]
fn prop_coordinator_stats_percentiles_monotone() {
    use scnn::coordinator::ServeStats;
    use std::time::Duration;
    prop("stats", 50, |g| {
        let mut s = ServeStats::new();
        let n = g.range(1, 200);
        for _ in 0..n {
            s.record(Duration::from_micros(g.range(1, 100_000)), g.range(1, 33) as usize);
        }
        let mut last = 0;
        for p in [0.0, 25.0, 50.0, 75.0, 99.0, 100.0] {
            let v = s.latency_percentile_us(p);
            assert!(v >= last, "percentiles must be monotone");
            last = v;
        }
    });
}
