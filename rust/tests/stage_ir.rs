//! Property tests for the stage IR (proptest-lite convention of
//! `tests/prop.rs`: seeded pseudo-random case generators, deterministic
//! replay via the printed seed).
//!
//! * shape inference accepts every randomly *grown* stack (layers are only
//!   appended when they fit) and the compiled stage chain is internally
//!   consistent (shapes chain, MAC totals match, weight layers number the
//!   compute stages);
//! * randomly *corrupted* stacks — channel mismatches, non-divisible
//!   pools, dense size drift, dangling or misshapen residuals, activation
//!   on pool layers — are rejected with an error, never a panic;
//! * on small random valid stacks, the fused stochastic engine and the
//!   per-bit reference (which lower the same descriptors) agree
//!   bit-for-bit — including under randomized injected fault plans
//!   (`scnn::faults`), which both datapaths must honor identically;
//! * the transposed bit-plane kernel is a third lowering of the same IR
//!   and must agree with both, on random topologies × random per-layer
//!   plans × random fault plans, and on the packing edge cases (fan-ins
//!   and stream lengths that are not multiples of the 64-lane word);
//! * a sparsity threshold compiled into the plan (magnitude pruning with
//!   the dropped lanes' 0.5-expectation folded into the stage bias) keeps
//!   all three lowerings bit-exact, and a 0.0 threshold reproduces the
//!   dense plan bit-for-bit.

use scnn::accel::layers::{Conv2d, LayerKind, LayerSpec, NetworkSpec, Shape};
use scnn::accel::network::{
    prune_stats, reference, ForwardMode, ForwardPlan, KernelPath, QuantizedWeights,
    SparsityPolicy,
};
use scnn::accel::precision::{autotune, AutoTuneConfig, PrecisionPlan, WORD};
use scnn::accel::stage::total_macs;
use scnn::faults::FaultPlan;

struct Gen(u64);

impl Gen {
    fn new(seed: u64) -> Self {
        Gen(seed.max(1))
    }
    fn next(&mut self) -> u64 {
        self.0 ^= self.0 << 13;
        self.0 ^= self.0 >> 7;
        self.0 ^= self.0 << 17;
        self.0
    }
    fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.next() % (hi - lo)
    }
    fn chance(&mut self, percent: u64) -> bool {
        self.next() % 100 < percent
    }
}

/// Grow a random valid network: every appended layer is checked to fit the
/// running shape, so the result must pass validation by construction.
fn grow_random_net(g: &mut Gen, max_layers: usize) -> NetworkSpec {
    let mut shape: Shape = (
        g.range(1, 4) as usize,
        2 * g.range(2, 7) as usize,
        2 * g.range(2, 7) as usize,
    );
    let input = shape;
    let mut layers: Vec<LayerSpec> = Vec::new();
    let mut out_shapes: Vec<Shape> = Vec::new();
    fn push(layers: &mut Vec<LayerSpec>, out_shapes: &mut Vec<Shape>, spec: LayerSpec, s: Shape) {
        layers.push(spec);
        out_shapes.push(s);
    }
    for _ in 0..max_layers {
        let (c, h, w) = shape;
        let pick = g.range(0, 100);
        if pick < 35 && h >= 2 && w >= 2 {
            // Conv: random kernel/stride/padding that fits.
            let kh = g.range(1, (h.min(3) + 1) as u64) as usize;
            let kw = g.range(1, (w.min(3) + 1) as u64) as usize;
            let stride = if g.chance(40) { 2 } else { 1 };
            let padding = if g.chance(50) { 1 } else { 0 };
            if h + 2 * padding < kh || w + 2 * padding < kw {
                continue;
            }
            let depthwise = g.chance(25);
            let out_ch = if depthwise { c } else { g.range(1, 5) as usize };
            let conv = Conv2d {
                in_ch: c,
                out_ch,
                kernel: (kh, kw),
                stride: (stride, stride),
                padding,
                depthwise,
            };
            let spec = LayerSpec { kind: LayerKind::Conv(conv), relu: g.chance(60) };
            let s = spec.try_output_shape(shape).unwrap();
            if s.1 == 0 || s.2 == 0 {
                continue;
            }
            push(&mut layers, &mut out_shapes, spec, s);
            shape = s;
        } else if pick < 55 && h % 2 == 0 && w % 2 == 0 && h >= 2 && w >= 2 {
            let kind = if g.chance(50) {
                LayerKind::MaxPool { size: 2 }
            } else {
                LayerKind::AvgPool { size: 2 }
            };
            let spec = LayerSpec::linear(kind);
            let s = spec.try_output_shape(shape).unwrap();
            push(&mut layers, &mut out_shapes, spec, s);
            shape = s;
        } else if pick < 65 && (h > 1 || w > 1) && g.chance(30) {
            let spec = LayerSpec::linear(LayerKind::GlobalAvgPool);
            let s = spec.try_output_shape(shape).unwrap();
            push(&mut layers, &mut out_shapes, spec, s);
            shape = s;
        } else if pick < 80 {
            // Residual: merge any earlier layer whose output matches.
            if let Some(from) = (0..out_shapes.len()).rev().find(|&i| out_shapes[i] == shape) {
                // Do not self-merge the immediately preceding identity
                // chain forever; one add per site is plenty.
                if !matches!(layers.last().map(|l| &l.kind), Some(LayerKind::Add { .. })) {
                    let spec = LayerSpec::linear(LayerKind::Add { from });
                    push(&mut layers, &mut out_shapes, spec, shape);
                }
            }
        }
    }
    // Always close with a dense classifier (guarantees a compute layer).
    let (c, h, w) = shape;
    let spec = LayerSpec::linear(LayerKind::Dense {
        inputs: c * h * w,
        outputs: g.range(2, 6) as usize,
    });
    let s = spec.try_output_shape(shape).unwrap();
    layers.push(spec);
    out_shapes.push(s);
    NetworkSpec { name: "grown".into(), input, layers }
}

/// Per-compute-layer fan-ins of a net's compiled stages — the lane bound
/// `FaultPlan::validate_sites` enforces at compile time, so random stuck
/// sites must be drawn inside it.
fn compute_fan_ins(net: &NetworkSpec) -> Vec<usize> {
    net.stages()
        .unwrap()
        .iter()
        .filter(|s| s.is_compute())
        .filter_map(|s| s.weight_shape().map(|(_, fan_in)| fan_in))
        .collect()
}

/// Run a property over `n` seeded cases; failures print the case seed.
fn prop(name: &str, n: usize, mut f: impl FnMut(&mut Gen)) {
    for case in 0..n {
        let seed = 0x57A6_E000 + case as u64;
        let mut g = Gen::new(seed);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut g)));
        if let Err(e) = r {
            panic!("property {name} failed at case seed {seed:#x}: {e:?}");
        }
    }
}

#[test]
fn prop_grown_stacks_validate_and_stage_chain_is_consistent() {
    prop("grown-valid", 200, |g| {
        let net = grow_random_net(g, g.range(1, 8) as usize);
        let shapes = net.validate().unwrap_or_else(|e| panic!("{}: {e:#}", net.name));
        assert_eq!(shapes.len(), net.layers.len());
        let stages = net.stages().unwrap();
        assert_eq!(stages.len(), net.layers.len());
        // Shapes chain stage to stage and match validate()'s inference.
        for (i, st) in stages.iter().enumerate() {
            assert_eq!(st.in_shape, shapes[i]);
            if i + 1 < stages.len() {
                assert_eq!(st.out_shape, stages[i + 1].in_shape);
            }
        }
        assert_eq!(stages.last().unwrap().out_shape, net.output_shape());
        // MAC totals agree between the IR and the layer walk.
        assert_eq!(total_macs(&stages), net.total_macs());
        // Weight layers number the compute stages contiguously.
        let wls: Vec<usize> = stages.iter().filter_map(|s| s.weight_layer).collect();
        assert_eq!(wls, (0..wls.len()).collect::<Vec<_>>());
        // Exactly one final compute stage, and it is the last compute one.
        let finals: Vec<usize> =
            stages.iter().filter(|s| s.final_compute).map(|s| s.index).collect();
        assert_eq!(finals.len(), 1);
        assert_eq!(
            finals[0],
            stages.iter().filter(|s| s.is_compute()).map(|s| s.index).max().unwrap()
        );
        // Residual targets are marked for saving.
        for st in &stages {
            if let scnn::accel::stage::StageOp::Add { from } = st.op {
                assert!(stages[from].save_output, "layer {from} feeds an add");
            }
        }
    });
}

#[test]
fn prop_corrupted_stacks_are_rejected_without_panicking() {
    prop("corrupted", 200, |g| {
        let net = grow_random_net(g, g.range(2, 8) as usize);
        let mut bad = net.clone();
        let corruption = g.range(0, 5);
        let applied = match corruption {
            0 => {
                // Channel drift on the first conv.
                bad.layers.iter_mut().any(|l| {
                    if let LayerKind::Conv(c) = &mut l.kind {
                        c.in_ch += 1;
                        true
                    } else {
                        false
                    }
                })
            }
            1 => {
                // Pool window that cannot divide the input.
                let shapes = net.validate().unwrap();
                let mut hit = false;
                for (i, l) in bad.layers.iter_mut().enumerate() {
                    if let LayerKind::MaxPool { size } | LayerKind::AvgPool { size } = &mut l.kind
                    {
                        let (_, h, _) = shapes[i];
                        if let Some(s) = (2..=h + 1).find(|s| h % s != 0) {
                            *size = s;
                            hit = true;
                            break;
                        }
                    }
                }
                hit
            }
            2 => {
                // Dense fan-in drift (the closing classifier always exists).
                if let Some(LayerKind::Dense { inputs, .. }) =
                    bad.layers.last_mut().map(|l| &mut l.kind)
                {
                    *inputs += 1;
                    true
                } else {
                    false
                }
            }
            3 => {
                // Residual pointing at itself (not an earlier layer).
                let mut hit = false;
                for (i, l) in bad.layers.iter_mut().enumerate() {
                    if let LayerKind::Add { from } = &mut l.kind {
                        *from = i;
                        hit = true;
                        break;
                    }
                }
                hit
            }
            _ => {
                // Activation on a non-compute layer.
                bad.layers.iter_mut().any(|l| {
                    if l.is_compute() {
                        false
                    } else {
                        l.relu = true;
                        true
                    }
                })
            }
        };
        if !applied {
            return; // this stack has no site for the chosen corruption
        }
        assert!(bad.validate().is_err(), "corruption {corruption} must be rejected");
        assert!(bad.stages().is_err());
        // And the plan compiler surfaces it as an error too (weights for
        // the *valid* twin do not matter — validation trips first).
        let w = QuantizedWeights::synthetic(&net, 6, 1).unwrap();
        assert!(ForwardPlan::compile(&bad, &w, ForwardMode::Expectation).is_err());
    });
}

#[test]
fn prop_random_per_layer_plans_fused_matches_reference_bit_exactly() {
    // Per-layer precision: random word-aligned k per compute stage
    // (adjacent stages almost always differ), fused vs per-bit reference
    // through the same plan — bit-for-bit, including the S2B→B2S
    // rescaling at every stage boundary.
    prop("per-layer-plans", 10, |g| {
        let net = grow_random_net(g, 3);
        let weights = QuantizedWeights::synthetic(&net, 8, g.next()).unwrap();
        let stages = net.stages().unwrap();
        let n_compute = stages.iter().filter(|s| s.is_compute()).count();
        let ks: Vec<usize> =
            (0..n_compute).map(|_| WORD * g.range(2, 15) as usize).collect();
        let plan = PrecisionPlan::per_layer(ks.clone());
        plan.validate_for(n_compute).unwrap();
        let in_len = net.input.0 * net.input.1 * net.input.2;
        let input: Vec<f64> = (0..in_len).map(|i| ((i % 7) as f64) / 7.0).collect();
        let seed = g.range(1, 1000) as u32;
        let mode = ForwardMode::Stochastic { k: plan.max_k(), seed };
        let fused = ForwardPlan::compile_with_precision(&net, &weights, mode, &plan)
            .unwrap()
            .run(&input);
        let golden =
            reference::forward_stochastic_plan(&net, &weights, &input, &plan, seed);
        assert_eq!(fused, golden, "ks={ks:?} seed={seed}");
        assert!(fused.iter().all(|v| v.is_finite()));
    });
}

#[test]
fn prop_random_fault_plans_keep_fused_and_reference_bit_exact() {
    // The resilience extension of the bit-exact contract: a seeded
    // `FaultPlan` (stream bit flips, SNG correlation collisions, SRAM
    // weight upsets, a stuck APC lane) is a pure function of the same
    // generation keys both datapaths use — so the fused word-level engine
    // and the per-bit reference must inject identical faults and stay
    // bit-for-bit, on random nets under random per-layer plans.
    prop("faulted-parity", 8, |g| {
        let net = grow_random_net(g, 3);
        let weights = QuantizedWeights::synthetic(&net, 8, g.next()).unwrap();
        let fan_ins = compute_fan_ins(&net);
        let n_compute = fan_ins.len();
        let ks: Vec<usize> = (0..n_compute).map(|_| WORD * g.range(2, 10) as usize).collect();
        let plan = PrecisionPlan::per_layer(ks.clone());
        let mut fp = FaultPlan::new(g.next())
            .with_bit_flip_rate(g.range(0, 50) as f64 / 1000.0)
            .with_sng_correlation_rate(g.range(0, 30) as f64 / 100.0)
            .with_sram_upset_rate(g.range(0, 20) as f64 / 1000.0);
        if g.chance(60) {
            // Sites are drawn inside the compiled plan: compile now
            // rejects out-of-bounds stuck lanes with a typed error.
            let wl = g.range(0, fan_ins.len() as u64) as usize;
            fp = fp.with_stuck_lane(wl, g.range(0, fan_ins[wl] as u64) as usize, g.chance(50));
        }
        let in_len = net.input.0 * net.input.1 * net.input.2;
        let input: Vec<f64> = (0..in_len).map(|i| ((i % 7) as f64) / 7.0).collect();
        let seed = g.range(1, 1000) as u32;
        let mode = ForwardMode::Stochastic { k: plan.max_k(), seed };
        let fused =
            ForwardPlan::compile_with_precision_faults(&net, &weights, mode, &plan, Some(&fp))
                .unwrap()
                .run(&input);
        let golden = reference::forward_stochastic_plan_faulted(
            &net,
            &weights,
            &input,
            &plan,
            seed,
            Some(&fp),
        );
        assert_eq!(fused, golden, "ks={ks:?} seed={seed} faults={fp:?}");
        assert!(fused.iter().all(|v| v.is_finite()));
    });
}

#[test]
fn prop_transposed_fused_reference_three_way_bit_exact() {
    // The kernel-path contract: the transposed bit-plane kernel, the fused
    // lane-major kernel, and the per-bit reference are three lowerings of
    // the same stage IR — bit-for-bit identical on random topologies under
    // random per-layer precision plans AND random fault plans.
    prop("kernel-three-way", 8, |g| {
        let net = grow_random_net(g, 3);
        let weights = QuantizedWeights::synthetic(&net, 8, g.next()).unwrap();
        let fan_ins = compute_fan_ins(&net);
        let n_compute = fan_ins.len();
        let ks: Vec<usize> = (0..n_compute).map(|_| WORD * g.range(2, 12) as usize).collect();
        let plan = PrecisionPlan::per_layer(ks.clone());
        let mut fp = FaultPlan::new(g.next())
            .with_bit_flip_rate(g.range(0, 40) as f64 / 1000.0)
            .with_sng_correlation_rate(g.range(0, 25) as f64 / 100.0)
            .with_sram_upset_rate(g.range(0, 15) as f64 / 1000.0);
        if g.chance(50) {
            let wl = g.range(0, fan_ins.len() as u64) as usize;
            fp = fp.with_stuck_lane(wl, g.range(0, fan_ins[wl] as u64) as usize, g.chance(50));
        }
        let faults = g.chance(70).then_some(&fp);
        let in_len = net.input.0 * net.input.1 * net.input.2;
        let input: Vec<f64> = (0..in_len).map(|i| ((i % 7) as f64) / 7.0).collect();
        let seed = g.range(1, 1000) as u32;
        let mode = ForwardMode::Stochastic { k: plan.max_k(), seed };
        let run = |kernel: KernelPath| {
            ForwardPlan::compile_with_opts(&net, &weights, mode, &plan, faults, kernel)
                .unwrap()
                .run(&input)
        };
        let transposed = run(KernelPath::Transposed);
        assert_eq!(transposed, run(KernelPath::Fused), "ks={ks:?} seed={seed} faults={fp:?}");
        let golden = reference::forward_stochastic_plan_faulted(
            &net, &weights, &input, &plan, seed, faults,
        );
        assert_eq!(transposed, golden, "ks={ks:?} seed={seed} faults={fp:?}");
        assert!(transposed.iter().all(|v| v.is_finite()));
    });
}

#[test]
fn prop_sparsity_thresholds_keep_three_kernels_and_reference_bit_exact() {
    // The sparsity extension of the bit-exact contract: magnitude pruning
    // at compile drops weight lanes into per-channel skip lists and folds
    // their 0.5-expectation into the stage bias, so the fused kernel, the
    // transposed bit-plane kernel, and the per-bit reference must still
    // agree bit-for-bit — on random topologies × random per-layer
    // precision plans × random fault plans × random thresholds. And a
    // 0.0 threshold must reproduce the dense plan bit-for-bit.
    prop("sparse-three-way", 8, |g| {
        let net = grow_random_net(g, 3);
        let weights = QuantizedWeights::synthetic(&net, 8, g.next()).unwrap();
        let fan_ins = compute_fan_ins(&net);
        let ks: Vec<usize> = (0..fan_ins.len()).map(|_| WORD * g.range(2, 10) as usize).collect();
        let plan = PrecisionPlan::per_layer(ks.clone());
        let mut fp = FaultPlan::new(g.next())
            .with_bit_flip_rate(g.range(0, 40) as f64 / 1000.0)
            .with_sng_correlation_rate(g.range(0, 25) as f64 / 100.0)
            .with_sram_upset_rate(g.range(0, 15) as f64 / 1000.0);
        if g.chance(50) {
            let wl = g.range(0, fan_ins.len() as u64) as usize;
            fp = fp.with_stuck_lane(wl, g.range(0, fan_ins[wl] as u64) as usize, g.chance(50));
        }
        let faults = g.chance(70).then_some(&fp);
        let in_len = net.input.0 * net.input.1 * net.input.2;
        let input: Vec<f64> = (0..in_len).map(|i| ((i % 7) as f64) / 7.0).collect();
        let seed = g.range(1, 1000) as u32;
        let mode = ForwardMode::Stochastic { k: plan.max_k(), seed };
        let compile = |kernel: KernelPath, s: SparsityPolicy| {
            ForwardPlan::compile_with_sparsity(&net, &weights, mode, &plan, faults, kernel, s)
        };
        // Threshold 0.0 is the dense plan, bit for bit, on every kernel.
        for kernel in [KernelPath::Transposed, KernelPath::Fused, KernelPath::Auto] {
            assert_eq!(
                compile(kernel, SparsityPolicy::threshold(0.0)).unwrap().run(&input),
                ForwardPlan::compile_with_opts(&net, &weights, mode, &plan, faults, kernel)
                    .unwrap()
                    .run(&input),
                "threshold 0.0 must reproduce the dense plan ({kernel:?})"
            );
        }
        // An active threshold can prune a whole channel dead on some
        // seeded weights — a typed compile error covered by unit tests;
        // such cases carry no parity to check, so skip them.
        let sparsity = SparsityPolicy::threshold(g.range(1, 40) as f64 / 100.0);
        let sparse_plan = match compile(KernelPath::Transposed, sparsity) {
            Ok(p) => p,
            Err(_) => return,
        };
        let transposed = sparse_plan.run(&input);
        let fused = compile(KernelPath::Fused, sparsity).unwrap().run(&input);
        assert_eq!(
            transposed, fused,
            "ks={ks:?} seed={seed} threshold={} faults={fp:?}",
            sparsity.threshold
        );
        let golden = reference::forward_stochastic_plan_sparse(
            &net, &weights, &input, &plan, seed, faults, sparsity,
        );
        assert_eq!(
            transposed, golden,
            "ks={ks:?} seed={seed} threshold={}",
            sparsity.threshold
        );
        assert!(transposed.iter().all(|v| v.is_finite()));
        // When lanes really were pruned (no SRAM fault re-writing the
        // tensor first), the compiled plan must account for the skips.
        let pruned: usize = prune_stats(&weights, sparsity).iter().map(|s| s.pruned).sum();
        if pruned > 0 && faults.is_none() {
            let (executed, skipped) = sparse_plan.ops_per_image();
            assert!(executed > 0);
            assert!(skipped > 0, "pruned {pruned} lanes but the plan reports no skipped ops");
        }
    });
}

#[test]
fn transposed_kernel_odd_fanin_odd_k_edge_cases() {
    // The packing edge cases of the bit-plane layout: fan-ins that are not
    // multiples of the 64-lane block (9, 25, 63, 65, 100 — tail lanes must
    // contribute exactly zero) against stream lengths that are WORD-aligned
    // but not 64-bit-word multiples (8, 104, 136 — tail cycles must be
    // clipped, not counted).
    for &(inputs, hidden) in &[(9usize, 5usize), (25, 3), (63, 4), (65, 4), (100, 2)] {
        let net = NetworkSpec {
            name: format!("odd-{inputs}"),
            input: (1, 1, inputs),
            layers: vec![
                LayerSpec::active(LayerKind::Dense { inputs, outputs: hidden }),
                LayerSpec::linear(LayerKind::Dense { inputs: hidden, outputs: 2 }),
            ],
        };
        let weights = QuantizedWeights::synthetic(&net, 8, inputs as u64).unwrap();
        let input: Vec<f64> = (0..inputs).map(|i| ((i % 9) as f64) / 9.0).collect();
        for k in [8usize, 104, 136] {
            let plan = PrecisionPlan::uniform(k, 2);
            let mode = ForwardMode::Stochastic { k, seed: 3 };
            let run = |kernel: KernelPath| {
                ForwardPlan::compile_with_opts(&net, &weights, mode, &plan, None, kernel)
                    .unwrap()
                    .run(&input)
            };
            let transposed = run(KernelPath::Transposed);
            assert_eq!(transposed, run(KernelPath::Fused), "inputs={inputs} k={k}");
            assert_eq!(
                transposed,
                reference::forward_stochastic(&net, &weights, &input, k, 3),
                "inputs={inputs} k={k}"
            );
        }
    }
}

#[test]
fn auto_tuned_plans_are_deterministic_for_a_fixed_seed() {
    // The Auto policy's contract: same (net, weights, seed, knobs) — same
    // plan, bit for bit; tuned stages stay word-aligned inside the
    // tuner's bounds and the resulting plan compiles and runs.
    let mut g = Gen::new(0xA07_0);
    let net = grow_random_net(&mut g, 2);
    let weights = QuantizedWeights::synthetic(&net, 8, 99).unwrap();
    let cfg = AutoTuneConfig {
        accuracy_budget: 0.25,
        k_max: 128,
        k_min: 16,
        calib_images: 5,
    };
    let a = autotune(&net, &weights, 13, &cfg).unwrap();
    let b = autotune(&net, &weights, 13, &cfg).unwrap();
    assert_eq!(a, b, "autotune must be deterministic for a fixed seed");
    for &k in a.ks() {
        assert!((cfg.k_min..=cfg.k_max).contains(&k));
        assert_eq!(k % WORD, 0);
    }
    let in_len = net.input.0 * net.input.1 * net.input.2;
    let input: Vec<f64> = (0..in_len).map(|i| ((i % 5) as f64) / 5.0).collect();
    let mode = ForwardMode::Stochastic { k: a.max_k(), seed: 13 };
    let fused =
        ForwardPlan::compile_with_precision(&net, &weights, mode, &a).unwrap().run(&input);
    assert_eq!(
        fused,
        reference::forward_stochastic_plan(&net, &weights, &input, &a, 13),
        "the tuned plan stays on the bit-exact contract"
    );
}

#[test]
fn prop_zero_analyzer_errors_imply_three_way_bit_exactness() {
    // The analyzer's closed-loop contract (`scnn::analyze`): a config it
    // passes with zero errors runs bit-exactly on all three lowerings of
    // the stage IR. Grown nets with in-bounds fault sites must analyze
    // clean — and then the fused, transposed, and per-bit paths agree.
    prop("analyze-clean-bit-exact", 8, |g| {
        let net = grow_random_net(g, 3);
        let weights = QuantizedWeights::synthetic(&net, 8, g.next()).unwrap();
        let fan_ins = compute_fan_ins(&net);
        let ks: Vec<usize> =
            (0..fan_ins.len()).map(|_| WORD * g.range(2, 12) as usize).collect();
        let plan = PrecisionPlan::per_layer(ks.clone());
        let mut fp = FaultPlan::new(g.next())
            .with_bit_flip_rate(g.range(0, 40) as f64 / 1000.0)
            .with_sng_correlation_rate(g.range(0, 25) as f64 / 100.0)
            .with_sram_upset_rate(g.range(0, 15) as f64 / 1000.0);
        if g.chance(50) {
            let wl = g.range(0, fan_ins.len() as u64) as usize;
            fp = fp.with_stuck_lane(wl, g.range(0, fan_ins[wl] as u64) as usize, g.chance(50));
        }
        let faults = g.chance(70).then_some(&fp);
        let report = scnn::analyze::analyze_network(&net, &plan, 8, faults);
        assert!(
            !report.has_errors(),
            "grown configs must analyze clean, got: {}",
            report.error_summary()
        );
        let in_len = net.input.0 * net.input.1 * net.input.2;
        let input: Vec<f64> = (0..in_len).map(|i| ((i % 7) as f64) / 7.0).collect();
        let seed = g.range(1, 1000) as u32;
        let mode = ForwardMode::Stochastic { k: plan.max_k(), seed };
        let run = |kernel: KernelPath| {
            ForwardPlan::compile_with_opts(&net, &weights, mode, &plan, faults, kernel)
                .unwrap()
                .run(&input)
        };
        let transposed = run(KernelPath::Transposed);
        assert_eq!(transposed, run(KernelPath::Fused), "ks={ks:?} seed={seed} faults={fp:?}");
        assert_eq!(
            transposed,
            reference::forward_stochastic_plan_faulted(
                &net, &weights, &input, &plan, seed, faults,
            ),
            "ks={ks:?} seed={seed} faults={fp:?}"
        );
    });
}

#[test]
fn seeded_collision_and_overflow_constructions_get_distinct_codes() {
    use scnn::analyze::{analyze_network, WEIGHT_LANE_SPAN};
    // A dense fan-in wider than the 2^20 weight-lane key span makes SNG
    // streams collide across output channels — flagged SC001, an error,
    // with no counter-width complaint on the side.
    let wide = NetworkSpec {
        name: "aliased".into(),
        input: (1, 1, WEIGHT_LANE_SPAN + 1),
        layers: vec![LayerSpec::linear(LayerKind::Dense {
            inputs: WEIGHT_LANE_SPAN + 1,
            outputs: 2,
        })],
    };
    let r = analyze_network(&wide, &PrecisionPlan::uniform(4 * WORD, 1), 8, None);
    assert!(r.has_errors());
    assert!(r.has_code("SC001"), "aliased keys must be SC001: {}", r.error_summary());
    assert!(!r.has_code("SC003"), "no width complaint on a narrow counter");

    // A stream length past the transposed kernel's 32-bit ones
    // accumulator overflows the popcount tally — flagged SC003, a
    // *different* code, on a topology whose key space is fine.
    let narrow = NetworkSpec {
        name: "overflow".into(),
        input: (1, 1, 4),
        layers: vec![LayerSpec::linear(LayerKind::Dense { inputs: 4, outputs: 2 })],
    };
    let k = 1usize << 32; // word-aligned and > u32::MAX
    let r = analyze_network(&narrow, &PrecisionPlan::uniform(k, 1), 8, None);
    assert!(r.has_errors());
    assert!(r.has_code("SC003"), "accumulator overflow must be SC003: {}", r.error_summary());
    assert!(!r.has_code("SC001"), "the key space itself is injective here");
}

#[test]
fn shipped_topologies_analyze_with_zero_errors_at_defaults() {
    // Every built-in network, analyzed at the CLI's defaults (8-bit
    // weights, k = 2^bits = 256, no faults), must report zero errors —
    // the same gate `scnn analyze --all` enforces in CI.
    for name in NetworkSpec::NAMES {
        let net = NetworkSpec::by_name(name).unwrap();
        let n_compute = compute_fan_ins(&net).len();
        let plan = PrecisionPlan::uniform(256, n_compute);
        let r = scnn::analyze::analyze_network(&net, &plan, 8, None);
        assert_eq!(r.error_count(), 0, "{name} must analyze clean: {}", r.error_summary());
    }
}

#[test]
fn prop_fused_and_reference_agree_on_random_small_stacks() {
    // The expensive cross-backend property: grown nets are valid by
    // construction and small (≤ 3 grown layers + the dense tail); keep the
    // case count modest — the per-bit reference is deliberately slow.
    prop("fused-vs-reference", 12, |g| {
        let net = grow_random_net(g, 3);
        let weights = QuantizedWeights::synthetic(&net, 8, g.next()).unwrap();
        let in_len = net.input.0 * net.input.1 * net.input.2;
        let input: Vec<f64> = (0..in_len).map(|i| ((i % 7) as f64) / 7.0).collect();
        let k = [32usize, 96][g.range(0, 2) as usize];
        let seed = g.range(1, 1000) as u32;
        let fused = ForwardPlan::once(&net, &weights, &input, ForwardMode::Stochastic { k, seed });
        let golden = reference::forward_stochastic(&net, &weights, &input, k, seed);
        assert_eq!(fused, golden, "k={k} seed={seed}");
    });
}
