//! Cross-module integration tests: gate netlists vs behavioral models,
//! calibration regressions, system-level invariants, artifact round-trips.

use scnn::accel::channel::{characterize_apc, characterize_pcc};
use scnn::accel::layers::NetworkSpec;
use scnn::accel::pipeline::{schedule_network, ScheduleConfig};
use scnn::accel::system::{evaluate, sweep_channels, SystemConfig};
use scnn::accel::memory::MemoryModel;
use scnn::sc::pcc::{build_netlist, pcc_bit, PccKind};
use scnn::sim::Evaluator;
use scnn::tech::calibration as cal;
use scnn::tech::{CellLibrary, TechKind};

#[test]
fn table1_full_calibration_regression() {
    let fin = CellLibrary::finfet10();
    let rf = CellLibrary::rfet10();
    let cases = [
        (characterize_pcc(&fin), cal::TABLE1_FINFET_PCC8),
        (characterize_pcc(&rf), cal::TABLE1_RFET_PCC8),
        (characterize_apc(&fin), cal::TABLE1_FINFET_APC25),
        (characterize_apc(&rf), cal::TABLE1_RFET_APC25),
    ];
    for (rep, target) in cases {
        assert!(cal::rel_err(rep.area_um2, target.area_um2) < 0.06, "{} area", rep.name);
        assert!(cal::rel_err(rep.delay_ps, target.delay_ps) < 0.06, "{} delay", rep.name);
        assert!(
            cal::rel_err(rep.energy_per_cycle_fj, target.energy_fj) < 0.06,
            "{} energy",
            rep.name
        );
    }
}

#[test]
fn paper_headline_gains_hold() {
    // §VI conclusions: RFET wins area/clock/energy/EDAP/TOPS metrics.
    let net = NetworkSpec::lenet5();
    let fin = evaluate(&SystemConfig::paper(TechKind::Finfet10, 8), &net);
    let rf = evaluate(&SystemConfig::paper(TechKind::Rfet10, 8), &net);
    assert!(rf.channel.area_um2 < fin.channel.area_um2);
    assert!(rf.channel.min_clock_ps < fin.channel.min_clock_ps);
    assert!(rf.channel.energy_per_cycle_fj < fin.channel.energy_per_cycle_fj);
    assert!(rf.metrics.edap() < fin.metrics.edap());
    assert!(rf.metrics.tops_per_watt() > 1.1 * fin.metrics.tops_per_watt());
}

#[test]
fn all_pcc_netlists_match_behavior_exhaustively_4bit() {
    for kind in PccKind::ALL {
        let nl = build_netlist(kind, 4);
        let mut ev = Evaluator::new(&nl);
        for x in 0..16u32 {
            for r in 0..16u32 {
                let mut pins = Vec::new();
                for i in 0..4 {
                    pins.push((x >> i) & 1 == 1);
                }
                for i in 0..4 {
                    pins.push((r >> i) & 1 == 1);
                }
                ev.set_inputs(&pins);
                ev.propagate();
                assert_eq!(ev.outputs()[0], pcc_bit(kind, x, r, 4), "{kind:?} {x} {r}");
            }
        }
    }
}

#[test]
fn pipeline_covers_all_three_regimes_on_lenet() {
    use scnn::accel::pipeline::PipelineMode;
    let net = NetworkSpec::lenet5();
    let mut seen = std::collections::HashSet::new();
    for channels in [1usize, 2, 4, 8, 16, 64] {
        let cfg = ScheduleConfig {
            channels,
            k: 32,
            clock_ps: 900.0,
            memory: MemoryModel::gddr5_paper(),
            bytes_per_operand: 1,
        };
        for l in schedule_network(&net, &cfg).layers {
            seen.insert(format!("{:?}", l.mode));
        }
    }
    assert!(seen.contains("FullyPipelined"), "{seen:?}");
    assert!(seen.contains("PartiallyPipelined") || seen.contains("NonPipelined"), "{seen:?}");
}

#[test]
fn sweep_is_deterministic() {
    let net = NetworkSpec::lenet5();
    let a = sweep_channels(TechKind::Rfet10, &net, &[4, 8]);
    let b = sweep_channels(TechKind::Rfet10, &net, &[4, 8]);
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.metrics.latency_us, y.metrics.latency_us);
        assert_eq!(x.metrics.energy_uj, y.metrics.energy_uj);
    }
}
