//! Chaos and fault-injection integration tests: the resilience contract of
//! ISSUE 6's acceptance criteria.
//!
//! * a seeded [`FaultPlan`] perturbs the fused engine and the per-bit
//!   reference identically — bit-exact parity holds under injected faults
//!   exactly as it does on the clean datapath;
//! * an injected worker panic (`EngineConfig::with_chaos_panic_after`)
//!   kills a pool shard mid-service and the router reroutes with only
//!   typed errors — zero client panics;
//! * a panic while holding the metrics lock poisons it without taking
//!   `Session::metrics` down (lock recovery);
//! * client deadlines resolve stuck requests to [`EngineError::Timeout`]
//!   instead of blocking forever;
//! * a sustained latency-SLO breach triggers the graceful-degradation
//!   fallback to a coarser precision plan — requests keep succeeding and
//!   the transition is visible in `SessionMetrics::degrade_events`;
//! * work queued before `close` is still served and drainable; new work
//!   is refused typed.

use scnn::accel::layers::{LayerKind, LayerSpec, NetworkSpec};
use scnn::accel::network::{reference, ForwardMode, ForwardPlan, LayerWeights, QuantizedWeights};
use scnn::accel::precision::PrecisionPlan;
use scnn::engine::{
    BackendKind, BatchPolicy, DegradePolicy, Engine, EngineConfig, EngineError, EnginePool,
    PoolConfig,
};
use scnn::faults::FaultPlan;
use scnn::sc::quantize_bipolar;
use std::time::Duration;

fn tiny_net() -> NetworkSpec {
    NetworkSpec {
        name: "faults-tiny".into(),
        input: (1, 4, 4),
        layers: vec![LayerSpec {
            kind: LayerKind::Dense { inputs: 16, outputs: 4 },
            relu: false,
        }],
    }
}

fn tiny_weights() -> QuantizedWeights {
    let codes: Vec<Vec<u32>> = (0..4)
        .map(|oc| {
            (0..16)
                .map(|j| quantize_bipolar(((oc * 3 + j) % 13) as f64 / 6.5 - 1.0, 8))
                .collect()
        })
        .collect();
    QuantizedWeights { bits: 8, layers: vec![LayerWeights { codes, gamma: 1.0, mu: 0.0 }] }
}

fn exp_cfg() -> EngineConfig {
    EngineConfig::new(BackendKind::Expectation, tiny_net()).with_quantized(tiny_weights())
}

fn fused_cfg(k: usize) -> EngineConfig {
    EngineConfig::new(BackendKind::StochasticFused, tiny_net())
        .with_quantized(tiny_weights())
        .with_k(k)
        .with_batch(BatchPolicy { linger: Duration::from_millis(1), ..BatchPolicy::default() })
}

fn images(n: usize) -> Vec<Vec<f32>> {
    (0..n).map(|i| (0..16).map(|j| ((i * 5 + j) % 11) as f32 / 11.0).collect()).collect()
}

#[test]
fn randomized_fault_plans_keep_fused_and_reference_bit_exact() {
    // Every fault class at once, at escalating rates: the fused word-level
    // engine and the per-bit reference must inject the *same* faults and
    // stay bit-for-bit identical (the clean-datapath contract, extended).
    let net = tiny_net();
    let weights = tiny_weights();
    let input: Vec<f64> = (0..16).map(|i| ((i % 7) as f64) / 7.0).collect();
    let plan = PrecisionPlan::uniform(64, 1);
    for case in 0..6u64 {
        let fp = FaultPlan::new(0xFA_417 + case)
            .with_bit_flip_rate(0.002 * case as f64)
            .with_sng_correlation_rate(0.05 * case as f64)
            .with_sram_upset_rate(0.001 * case as f64)
            .with_stuck_lane(0, case as usize % 4, case % 2 == 0);
        let fused = ForwardPlan::compile_with_precision_faults(
            &net,
            &weights,
            ForwardMode::Stochastic { k: 64, seed: 9 },
            &plan,
            Some(&fp),
        )
        .unwrap()
        .run(&input);
        let golden =
            reference::forward_stochastic_plan_faulted(&net, &weights, &input, &plan, 9, Some(&fp));
        assert_eq!(fused, golden, "fault case {case}");
        assert!(fused.iter().all(|v| v.is_finite()));
    }
}

#[test]
fn injected_worker_panic_reroutes_with_only_typed_errors() {
    // One shard is rigged to panic after serving two requests; the pool
    // must detect the death, mark the shard unhealthy, and serve every
    // client request from the survivor — no panics reach the client.
    let imgs = images(10);
    let single = Engine::open(exp_cfg()).unwrap();
    let expected = single.infer_batch(&imgs).unwrap();
    let chaos = exp_cfg().with_chaos_panic_after(2);
    let pool = EnginePool::open(PoolConfig::heterogeneous(vec![chaos, exp_cfg()])).unwrap();
    for (i, img) in imgs.iter().enumerate() {
        assert_eq!(pool.infer(img.clone()).unwrap(), expected[i], "image {i}");
    }
    let m = pool.metrics();
    assert_eq!(m.healthy, 1, "the chaos shard died and was detected");
    assert!(m.rerouted >= 1, "its traffic was rerouted to the survivor");
    // The poisoned shard's metrics still aggregate (lock recovery).
    assert!(m.requests >= imgs.len());
}

#[test]
fn metrics_survive_a_panic_that_poisons_the_recorder_lock() {
    // The chaos panic fires while the worker holds the metrics lock; the
    // session must recover the poisoned lock instead of propagating the
    // panic, and later requests must fail typed.
    let s = Engine::open(exp_cfg().with_chaos_panic_after(1)).unwrap();
    let img = images(1).pop().unwrap();
    assert!(s.infer(img.clone()).is_ok(), "the request before the panic succeeds");
    while s.worker_alive() {
        std::thread::sleep(Duration::from_millis(1));
    }
    let m = s.metrics();
    assert_eq!(m.requests, 1, "metrics survive the poisoned lock");
    match EngineError::from_request(s.infer(img).unwrap_err()) {
        EngineError::WorkerDied => {}
        other => panic!("expected WorkerDied, got {other:?}"),
    }
}

#[test]
fn deadline_breaches_resolve_typed_and_count_in_metrics() {
    // A 2 ms client deadline against a shard injected to sleep 300 ms per
    // batch: `infer` must return `EngineError::Timeout` instead of
    // blocking for the worker.
    let cfg = exp_cfg()
        .with_deadline(Duration::from_millis(2))
        .with_chaos_slow(Duration::from_millis(300));
    let s = Engine::open(cfg).unwrap();
    match EngineError::from_request(s.infer(images(1).pop().unwrap()).unwrap_err()) {
        EngineError::Timeout { elapsed } => assert!(elapsed >= Duration::from_millis(2)),
        other => panic!("expected Timeout, got {other:?}"),
    }
    assert_eq!(s.metrics().timeouts, 1);
}

#[test]
fn latency_slo_breach_degrades_precision_instead_of_failing() {
    // An impossible SLO (zero latency budget) breached on every batch: the
    // worker must fall back to coarser precision plans — visible in the
    // metrics — while every request keeps succeeding.
    let cfg = fused_cfg(64).with_degrade(DegradePolicy {
        latency_slo: Duration::ZERO,
        breach_window: 2,
        min_k: 8,
    });
    let s = Engine::open(cfg).unwrap();
    for img in images(8) {
        assert_eq!(s.infer(img).unwrap().len(), 4, "requests keep succeeding");
    }
    let m = s.metrics();
    assert!(m.degrade_events >= 1, "the SLO breach triggered a precision fallback");
    assert_eq!(m.requests, 8);
}

#[test]
fn work_queued_before_close_survives_and_new_work_is_refused_typed() {
    let s = Engine::open(exp_cfg()).unwrap();
    let imgs = images(4);
    let mut tickets = Vec::new();
    for img in &imgs {
        tickets.push(s.submit(img.clone()).unwrap());
    }
    s.close();
    assert!(s.is_closed());
    match s.submit(imgs[0].clone()) {
        Err(EngineError::Closed) => {}
        other => panic!("expected Closed, got {other:?}"),
    }
    // Queued-before-close work was executed and is still drainable.
    let drained = s.drain().unwrap();
    assert_eq!(drained.len(), 4);
    for (i, (ticket, res)) in drained.iter().enumerate() {
        assert_eq!(*ticket, tickets[i]);
        assert!(res.is_ok(), "queued request {i} served across close");
    }
    match s.drain() {
        Err(EngineError::EmptyQueue) => {}
        other => panic!("expected EmptyQueue, got {other:?}"),
    }
}
