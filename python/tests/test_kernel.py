"""L1 kernel correctness: Pallas (interpret=True) vs pure refs.

Hypothesis sweeps shapes and values; these are the core correctness signal
for the compile path (the Rust side replays the same conventions).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import mac, pcc, ref, sc_mac


@settings(max_examples=25, deadline=None)
@given(
    n=st.sampled_from([1, 8, 16, 24]),
    fan_in=st.integers(1, 64),
    words=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_sc_mac_matches_ref(n, fan_in, words, seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 2**32, size=(n, fan_in, words), dtype=np.uint32)
    w = rng.integers(0, 2**32, size=(n, fan_in, words), dtype=np.uint32)
    out = np.asarray(sc_mac.sc_mac(a, w))
    assert np.array_equal(out, ref.sc_mac_ref(a, w))


def test_sc_mac_extremes():
    ones = np.full((8, 25, 2), 0xFFFFFFFF, dtype=np.uint32)
    zeros = np.zeros((8, 25, 2), dtype=np.uint32)
    # XNOR(1,1) = 1 everywhere; XNOR(1,0) = 0 everywhere.
    assert np.all(np.asarray(sc_mac.sc_mac(ones, ones)) == 25 * 64)
    assert np.all(np.asarray(sc_mac.sc_mac(ones, zeros)) == 0)
    assert np.all(np.asarray(sc_mac.sc_mac(zeros, zeros)) == 25 * 64)


@settings(max_examples=20, deadline=None)
@given(
    kind=st.sampled_from(["cmp", "mux", "nandnor"]),
    bits=st.sampled_from([3, 4, 8, 10]),
    n=st.sampled_from([8, 16]),
    k=st.sampled_from([32, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_pcc_kernel_matches_ref(kind, bits, n, k, seed):
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, 1 << bits, size=n, dtype=np.uint32)
    rs = rng.integers(0, 1 << bits, size=k, dtype=np.uint32)
    out = np.asarray(pcc.pcc_streams(codes, rs, kind=kind, bits=bits))
    assert np.array_equal(out, ref.pcc_streams_packed(kind, codes, rs, bits))


def test_pcc_nandnor_transfer_is_monotone():
    # Lemma 1: expected output increases with the input code (Fig. 7).
    bits = 8
    codes = np.arange(256, dtype=np.uint32)
    rs = np.arange(256, dtype=np.uint32)  # exhaustive uniform R
    means = ref.pcc_bit("nandnor", codes[:, None], rs[None, :], bits).mean(axis=1)
    assert np.all(np.diff(means) >= -1e-12)
    # Bias stays within ~one LSB of x/2^N.
    assert np.abs(means - codes / 256.0).max() <= 1.6 / 256.0 + 1e-9


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 80),
    k=st.integers(1, 90),
    n=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_numpy(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(m, k)).astype(np.float32)
    b = rng.normal(size=(k, n)).astype(np.float32)
    c = np.asarray(mac.matmul(a, b))
    np.testing.assert_allclose(c, a @ b, rtol=1e-4, atol=1e-4)


def test_quantize_matches_rust_convention():
    # Mirrors rust sc::quantize_bipolar: round-half-away, clamp, cap.
    assert float(ref.quantize_bipolar(-1.0, 8)) == 0
    assert float(ref.quantize_bipolar(1.0, 8)) == 255
    assert float(ref.quantize_bipolar(0.0, 8)) == 128
    assert float(ref.quantize_bipolar(5.0, 4)) == 15


@settings(max_examples=30, deadline=None)
@given(v=st.floats(-1.0, 1.0), bits=st.sampled_from([3, 5, 8]))
def test_quantize_roundtrip_error_bounded(v, bits):
    q = float(ref.quantize_value(np.float32(v), bits))
    assert abs(q - v) <= 1.0 / (1 << bits) + 1e-6
