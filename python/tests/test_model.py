"""L2 model correctness: forward modes, shapes, SC math properties."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import data, model
from compile.kernels import ref


@pytest.fixture(scope="module")
def lenet_setup():
    params = model.init_params(model.LENET5, seed=0)
    x, _ = data.make_digits(16, seed=3)
    params = model.calibrate(params, jnp.asarray(x), model.LENET5, mode="sc", bits=8)
    return params, jnp.asarray(x)


def test_forward_shapes(lenet_setup):
    params, x = lenet_setup
    for mode in ("float", "fixed", "sc"):
        out = model.predict(params, x, "lenet5", mode=mode)
        assert out.shape == (16, 10)
        assert bool(jnp.all(jnp.isfinite(out)))


def test_pallas_and_jnp_paths_agree(lenet_setup):
    params, x = lenet_setup
    a = model.predict(params, x, "lenet5", mode="sc", use_pallas=False)
    b = model.predict(params, x, "lenet5", mode="sc", use_pallas=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


def test_sc_smooth_relu_upper_bounds_hard():
    # E[max(2c, n)] >= max(E[2c], n): the SC ReLU sits above the hard one.
    for pre in (-3.0, -0.5, 0.0, 0.5, 3.0):
        hard = ref.neuron_expectation(jnp.float32(pre), 25, False)
        hard_relu = (max(pre, 0.0) + 25) / 32.0 - 1.0
        smooth = float(ref.neuron_expectation(jnp.float32(pre), 25, True, var=jnp.float32(25.0)))
        assert smooth >= hard_relu - 1e-6
        del hard


def test_smooth_relu_converges_to_hard_when_noiseless():
    for pre in (-2.0, -0.1, 0.0, 0.1, 2.0):
        smooth = float(
            ref.neuron_expectation(jnp.float32(pre), 25, True, var=jnp.float32(1e-10))
        )
        hard = (max(pre, 0.0) + 25) / 32.0 - 1.0
        assert abs(smooth - hard) < 1e-4


def test_calibration_places_activations_in_range(lenet_setup):
    params, x = lenet_setup
    # After calibration the logits must differ across images (signal flows).
    out = np.asarray(model.predict(params, x, "lenet5", mode="sc"))
    assert out.std(axis=0).mean() > 1e-3


def test_cifar_net_shapes():
    params = model.init_params(model.CIFAR_NET, seed=1)
    x, _ = data.make_textures(4, seed=5)
    out = model.predict(params, jnp.asarray(x), "cifar_net", mode="float")
    assert out.shape == (4, 10)


def test_datasets_deterministic():
    a1, l1 = data.make_digits(8, seed=7)
    a2, l2 = data.make_digits(8, seed=7)
    assert np.array_equal(a1, a2) and np.array_equal(l1, l2)
    t1, m1 = data.make_textures(8, seed=7)
    t2, m2 = data.make_textures(8, seed=7)
    assert np.array_equal(t1, t2) and np.array_equal(m1, m2)


def test_dataset_ranges_and_classes():
    x, y = data.make_digits(64, seed=0)
    assert x.min() >= 0.0 and x.max() <= 1.0
    assert set(np.unique(y)).issubset(set(range(10)))
    x, y = data.make_textures(64, seed=0)
    assert x.shape == (64, 3, 32, 32)
    assert x.min() >= 0.0 and x.max() <= 1.0
