"""L2: the paper's networks in JAX with the SC-equivalent forward model.

Three forward modes over the same parameters (mirroring the Rust
``accel::network::ForwardMode``):

* ``float``  — ordinary conv/ReLU/pool/dense (training reference);
* ``fixed``  — quantize-dequantize weights+activations, hard ReLU (the
               Fig. 12 "binary fixed-point NN" baseline);
* ``sc``     — the SC-equivalent math model the paper trains through
               (section V-B): quantized operands, the APC/B2S affine
               v = (pre + n)/2^m - 1, and the *smoothed* ReLU that the
               correlated-OR hardware actually implements.

Layer boundary: the S2B counter recovers sp = softplus_sc(pre) exactly
(sp = (v+1)*2^m - n); the binary-domain re-encoder then applies a per-layer
trained affine a_next = clip(g*(sp - mu), 0, 1) before the next SNG. This
is the programmable-scale B2S/SNG boundary every fixed-point accelerator
needs (one multiply-add per activation in the binary domain) — without it
the SC bias term (sigma*phi(0) per neuron) eats the 8-bit activation range
and the network cannot train. The Rust bit-exact path
(rust/src/accel/network.rs) applies the identical affine.

The inference-export variant routes every MAC through the L1 Pallas matmul
kernel (conv via im2col), so the AOT-lowered HLO contains the kernel's
tiling; training uses the identical math in plain jnp.

Networks carry no biases — the SC neuron (Fig. 2) has none.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .kernels import mac as mac_kernel
from .kernels import ref

# Layer descriptors: mirror rust/src/accel/layers.rs.
LENET5 = {
    "name": "lenet5",
    "input": (1, 28, 28),
    "layers": [
        {"kind": "conv", "in_ch": 1, "out_ch": 6, "kernel": 5, "pad": 2, "relu": True, "pool": 2},
        {"kind": "conv", "in_ch": 6, "out_ch": 16, "kernel": 5, "pad": 0, "relu": True, "pool": 2},
        {"kind": "dense", "in": 400, "out": 120, "relu": True},
        {"kind": "dense", "in": 120, "out": 84, "relu": True},
        {"kind": "dense", "in": 84, "out": 10, "relu": False},
    ],
}

CIFAR_NET = {
    "name": "cifar_net",
    "input": (3, 32, 32),
    "layers": [
        {"kind": "conv", "in_ch": 3, "out_ch": 32, "kernel": 5, "pad": 2, "relu": True, "pool": 2},
        {"kind": "conv", "in_ch": 32, "out_ch": 32, "kernel": 5, "pad": 2, "relu": True, "pool": 2},
        {"kind": "conv", "in_ch": 32, "out_ch": 64, "kernel": 5, "pad": 2, "relu": True, "pool": 2},
        {"kind": "dense", "in": 1024, "out": 10, "relu": False},
    ],
}


def spec_by_name(name: str) -> dict:
    if name == "lenet5":
        return LENET5
    if name == "cifar_net":
        return CIFAR_NET
    raise ValueError(name)


def layer_fan_in(layer: dict) -> int:
    return layer["in_ch"] * layer["kernel"] ** 2 if layer["kind"] == "conv" else layer["in"]


def init_params(spec: dict, seed: int = 0) -> list[dict]:
    """Per layer: weights w, re-encoder gain g and offset mu (scalars)."""
    rng = np.random.default_rng(seed)
    params = []
    for layer in spec["layers"]:
        fan_in = layer_fan_in(layer)
        if layer["kind"] == "conv":
            shape = (layer["out_ch"], layer["in_ch"], layer["kernel"], layer["kernel"])
        else:
            shape = (layer["out"], layer["in"])
        w = rng.normal(0, 1.2 / np.sqrt(fan_in), size=shape)
        params.append(
            {
                "w": jnp.asarray(w, dtype=jnp.float32),
                "g": jnp.asarray(1.0, dtype=jnp.float32),
                "mu": jnp.asarray(0.0, dtype=jnp.float32),
            }
        )
    return params


def _collect_sp(params, x, spec, mode, bits, upto):
    """Forward through layer `upto` and return that layer's sp tensor
    (pre-affine). Used only by `calibrate`."""
    b = x.shape[0]
    act = x
    for li, (layer, p) in enumerate(zip(spec["layers"], params)):
        w, g, mu = p["w"], p["g"], p["mu"]
        final = li == len(spec["layers"]) - 1
        wc = jnp.clip(w, -1.0, 1.0)
        if mode in ("fixed", "sc"):
            aq = ref.quantize_value(act, bits)
            wq = ref.quantize_value(wc, bits)
        else:
            aq, wq = act, wc
        if layer["kind"] == "conv":
            cols, oh, ow = _im2col(aq, layer["kernel"], layer["pad"])
            fan_in = layer_fan_in(layer)
            wmat = wq.reshape(layer["out_ch"], fan_in).T
            pre = (cols.reshape(-1, fan_in) @ wmat).reshape(b, oh * ow, layer["out_ch"])
            var = None
            if mode == "sc":
                var = fan_in - ((cols * cols).reshape(-1, fan_in) @ (wmat * wmat)).reshape(
                    b, oh * ow, layer["out_ch"]
                )
            if mode == "sc":
                v = ref.neuron_expectation(pre, fan_in, layer["relu"], var)
                sp = (v + 1.0) * float(1 << ref.m_bits(fan_in)) - fan_in
            else:
                sp = jnp.maximum(pre, 0.0) if layer["relu"] else pre
            if li == upto:
                return sp
            out = jnp.clip(g * (sp - mu), 0.0, 1.0)
            out = out.transpose(0, 2, 1).reshape(b, layer["out_ch"], oh, ow)
            if layer.get("pool"):
                out = _max_pool(out, layer["pool"])
            act = out
        else:
            a2d = aq.reshape(b, -1)
            fan_in = layer["in"]
            pre = a2d @ wq.T
            if mode == "sc":
                var = fan_in - (a2d * a2d) @ (wq * wq).T
                v = ref.neuron_expectation(pre, fan_in, layer["relu"], var)
                sp = (v + 1.0) * float(1 << ref.m_bits(fan_in)) - fan_in
            else:
                sp = jnp.maximum(pre, 0.0) if layer["relu"] else pre
            if li == upto:
                return sp
            act = jnp.clip(g * (sp - mu), 0.0, 1.0) if not final else g * (sp - mu)
    raise ValueError("upto out of range")


def calibrate(params, x, spec, mode="sc", bits=8):
    """Data-driven init of the per-layer re-encoder affine (g, mu): place
    each layer's sp distribution into the quantizable [0, 1] window
    (mu = mean - std, g = 0.35/std), and give the logits a unit-std scale.
    The calibrated values train further with the weights."""
    params = [dict(p) for p in params]
    n_layers = len(spec["layers"])
    for li in range(n_layers):
        sp = _collect_sp(params, x, spec, mode, bits, li)
        mean = float(jnp.mean(sp))
        std = float(jnp.std(sp)) + 1e-6
        if li == n_layers - 1:
            params[li]["g"] = jnp.asarray(4.0 / std, dtype=jnp.float32)
            params[li]["mu"] = jnp.asarray(mean, dtype=jnp.float32)
        else:
            params[li]["g"] = jnp.asarray(0.35 / std, dtype=jnp.float32)
            params[li]["mu"] = jnp.asarray(mean - std, dtype=jnp.float32)
    return params


def _im2col(x: jnp.ndarray, kernel: int, pad: int):
    """x (B, C, H, W) -> ((B, OH*OW, C*k*k), OH, OW); ordering (c, ky, kx)
    matches rust conv_gather."""
    b, c, h, w = x.shape
    xp = jnp.pad(x, ((0, 0), (0, 0), (pad, pad), (pad, pad)))
    oh = h + 2 * pad - kernel + 1
    ow = w + 2 * pad - kernel + 1
    cols = []
    for ky in range(kernel):
        for kx in range(kernel):
            cols.append(xp[:, :, ky : ky + oh, kx : kx + ow])
    stacked = jnp.stack(cols, axis=0).transpose(1, 3, 4, 2, 0)
    return stacked.reshape(b, oh * ow, c * kernel * kernel), oh, ow


def _max_pool(x: jnp.ndarray, size: int) -> jnp.ndarray:
    b, c, h, w = x.shape
    return x.reshape(b, c, h // size, size, w // size, size).max(axis=(3, 5))


def _mac(a2d: jnp.ndarray, w2d: jnp.ndarray, use_pallas: bool) -> jnp.ndarray:
    """(M, K) @ (K, N) through the L1 kernel or plain jnp."""
    if use_pallas:
        return mac_kernel.matmul(a2d, w2d)
    return a2d @ w2d


def _layer_transfer(pre, var, fan_in, relu, mode, g, mu, final, noise):
    """pre-activation -> next-layer activation (or logits when final).

    ``noise``: optional (key, k) — inject the bitstream sampling noise of a
    k-cycle stream into the SC value, sigma_v = sqrt(P(1-P)/k). Training
    with this noise in the loop is what pushes the learned pre-activations
    above the SC noise floor (the paper trains through its SC math model
    for the same reason); without it the network learns signals far smaller
    than the k=32 sampling noise and the bit-exact datapath classifies at
    chance.
    """
    if mode == "sc":
        v = ref.neuron_expectation(pre, fan_in, relu, var)
        if noise is not None:
            key, kbits, scale = noise
            p = (v + 1.0) / 2.0
            # 1 sigma from the B2S/S2B resampling + ~0.5 sigma from the
            # product-stream sampling feeding the counts.
            sigma = 1.5 * jnp.sqrt(jnp.clip(p * (1.0 - p), 1e-6, 0.25) / kbits)
            v = v + scale * sigma * jax.random.normal(key, v.shape)
        # S2B recovery: sp == smoothed-relu(pre) (or pre itself, no relu).
        sp = (v + 1.0) * float(1 << ref.m_bits(fan_in)) - fan_in
    else:
        sp = jnp.maximum(pre, 0.0) if relu else pre
    if final:
        return g * (sp - mu)
    return jnp.clip(g * (sp - mu), 0.0, 1.0)


def forward(params, x, spec: dict, mode: str = "sc", bits: int = 8,
            use_pallas: bool = False, noise_key=None, noise_k: int = 32,
            noise_scale: float = 1.0) -> jnp.ndarray:
    """Forward pass. x: (B, C, H, W) in [0, 1]. Returns (B, 10) logits.

    ``noise_key``: inject k-cycle SC sampling noise (training only — the
    exported inference graph stays deterministic)."""
    b = x.shape[0]
    act = x
    n_layers = len(spec["layers"])
    keys = (
        jax.random.split(noise_key, n_layers) if noise_key is not None else [None] * n_layers
    )
    for li, (layer, p) in enumerate(zip(spec["layers"], params)):
        w = p["w"]
        g, mu = p["g"], p["mu"]
        final = li == n_layers - 1
        wc = jnp.clip(w, -1.0, 1.0)
        if mode in ("fixed", "sc"):
            # Straight-through quantization.
            aq = act + lax.stop_gradient(ref.quantize_value(act, bits) - act)
            wq = wc + lax.stop_gradient(ref.quantize_value(wc, bits) - wc)
        else:
            aq, wq = act, wc

        if layer["kind"] == "conv":
            cols, oh, ow = _im2col(aq, layer["kernel"], layer["pad"])
            fan_in = layer_fan_in(layer)
            wmat = wq.reshape(layer["out_ch"], fan_in).T
            pre = _mac(cols.reshape(-1, fan_in), wmat, use_pallas)
            pre = pre.reshape(b, oh * ow, layer["out_ch"])
            var = None
            if mode == "sc":
                var = fan_in - _mac(
                    (cols * cols).reshape(-1, fan_in), wmat * wmat, use_pallas
                ).reshape(b, oh * ow, layer["out_ch"])
            noise = (keys[li], noise_k, noise_scale) if keys[li] is not None else None
            out = _layer_transfer(pre, var, fan_in, layer["relu"], mode, g, mu, final, noise)
            out = out.transpose(0, 2, 1).reshape(b, layer["out_ch"], oh, ow)
            if layer.get("pool"):
                out = _max_pool(out, layer["pool"])
            act = out
        else:
            a2d = aq.reshape(b, -1)
            fan_in = layer["in"]
            pre = _mac(a2d, wq.T, use_pallas)
            var = None
            if mode == "sc":
                var = fan_in - _mac(a2d * a2d, (wq * wq).T, use_pallas)
            noise = (keys[li], noise_k, noise_scale) if keys[li] is not None else None
            act = _layer_transfer(pre, var, fan_in, layer["relu"], mode, g, mu, final, noise)
    return act


@functools.partial(
    jax.jit,
    static_argnames=("spec_name", "mode", "bits", "use_pallas", "noise_k", "noise_scale"),
)
def predict(params, x, spec_name: str, mode: str = "sc", bits: int = 8,
            use_pallas: bool = False, noise_key=None, noise_k: int = 32,
            noise_scale: float = 1.0):
    """Class logits."""
    spec = spec_by_name(spec_name)
    return forward(
        params, x, spec, mode=mode, bits=bits, use_pallas=use_pallas,
        noise_key=noise_key, noise_k=noise_k, noise_scale=noise_scale,
    )
