"""L1 Pallas kernel: tiled f32 matmul used by the exported inference graph.

Every MAC in the L2 model (conv layers via im2col, dense layers directly)
lowers through this kernel so the whole network's arithmetic sits in the L1
tile. Blocks are sized for VMEM residency of one (M_tile x K) activation
panel and one (K x N_tile) weight panel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_M = 32
BLOCK_N = 16


def _matmul_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.dot(a_ref[...], b_ref[...], precision="highest")


@functools.partial(jax.jit, static_argnames=("interpret",))
def matmul(a, b, *, interpret: bool = True):
    """C = A @ B with A (M, K) f32, B (K, N) f32.

    M and N are padded up to the block multiples internally; K stays whole
    (the reduction dimension lives in one block — fan-ins here are <= 1024).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    mp = -(-m // BLOCK_M) * BLOCK_M
    np_ = -(-n // BLOCK_N) * BLOCK_N
    a_pad = jnp.pad(a, ((0, mp - m), (0, 0)))
    b_pad = jnp.pad(b, ((0, 0), (0, np_ - n)))
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // BLOCK_M, np_ // BLOCK_N),
        in_specs=[
            pl.BlockSpec((BLOCK_M, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, BLOCK_N), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((BLOCK_M, BLOCK_N), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(a_pad, b_pad)
    return out[:m, :n]
