"""L1 Pallas kernel: NAND-NOR PCC stream generation (Lemma 1).

Converts binary codes into packed stochastic bitstreams with the paper's
RFET NAND-NOR reconfigurable chain, vectorized over (codes x cycles): the
chain recurrence runs over the N stages while 32 cycles are packed per
uint32 word. The comparator PCC is included for the correlated activation
banks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _nandnor_inverted(n: int, i: int) -> bool:
    """Lemma 1 parity rule (mirrors ref.nandnor_stage_inverted)."""
    return (i % 2 == 0) if n % 2 == 0 else (i % 2 == 1)


def _pcc_kernel_factory(kind: str, bits: int):
    def kernel(x_ref, r_ref, o_ref):
        # x: (BN,) codes; r: (k,) randoms; out: (BN, k/32) packed words.
        x = x_ref[...].astype(jnp.uint32)[:, None]  # (BN, 1)
        r = r_ref[...].astype(jnp.uint32)[None, :]  # (1, k)
        if kind == "cmp":
            bit = x > r
        elif kind == "nandnor":
            o = jnp.zeros(jnp.broadcast_shapes(x.shape, r.shape), dtype=bool)
            for i in range(1, bits + 1):
                xi = ((x >> (i - 1)) & 1) == 1
                ri = ((r >> (i - 1)) & 1) == 1
                prog = ~xi if _nandnor_inverted(bits, i) else xi
                o = jnp.where(prog, ~(o | ri), ~(o & ri))
            bit = o
        else:  # mux
            o = jnp.zeros(jnp.broadcast_shapes(x.shape, r.shape), dtype=bool)
            for i in range(bits):
                xi = ((x >> i) & 1) == 1
                ri = ((r >> i) & 1) == 1
                o = jnp.where(ri, xi, o)
            bit = o
        k = bit.shape[1]
        b = bit.reshape(bit.shape[0], k // 32, 32).astype(jnp.uint32)
        shifts = jnp.arange(32, dtype=jnp.uint32)[None, None, :]
        o_ref[...] = jnp.sum(b << shifts, axis=2).astype(jnp.uint32)

    return kernel


@functools.partial(
    jax.jit, static_argnames=("kind", "bits", "interpret")
)
def pcc_streams(codes, rs, *, kind: str = "nandnor", bits: int = 8, interpret: bool = True):
    """Packed PCC streams.

    codes: uint32 (n,); rs: uint32 (k,) with k % 32 == 0. Returns uint32
    (n, k/32) packed streams (bit t of word w = cycle 32w + t).
    """
    n = codes.shape[0]
    k = rs.shape[0]
    assert k % 32 == 0, "k must be a multiple of 32"
    bn = 8 if n % 8 == 0 else 1
    return pl.pallas_call(
        _pcc_kernel_factory(kind, bits),
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bn, k // 32), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k // 32), jnp.uint32),
        interpret=interpret,
    )(codes, rs)
