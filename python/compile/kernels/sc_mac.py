"""L1 Pallas kernel: the SC compute hot-spot.

Packed-bitstream XNOR multiply + population-count accumulate — the software
image of the paper's 25-multiplier + APC MAC unit (Fig. 9). Bitstreams are
packed 32 SC cycles per uint32 lane, so one vector op advances 32 clock
cycles of the stochastic datapath.

Hardware adaptation (DESIGN.md section Hardware-Adaptation): the iteration
space (neurons x fan_in x words) is tiled with BlockSpec so one block's
activation/weight words sit in VMEM (the analogue of the paper's ping-pong
on-chip buffers); the reduction is VPU-bound (popcount + add), not MXU.

Kernels must run with interpret=True here: real TPU lowering emits a Mosaic
custom-call the CPU PJRT client cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

# Neurons processed per grid step (VMEM tile height). 8 keeps the tile
# under a few KB for fan-in 400 x 8 words while saturating the lanes.
BLOCK_NEURONS = 8


def _sc_mac_kernel(a_ref, w_ref, o_ref):
    """One block: (BN, fan_in, words) uint32 -> (BN,) uint32 counts."""
    prod = ~(a_ref[...] ^ w_ref[...])
    counts = lax.population_count(prod)
    o_ref[...] = jnp.sum(counts, axis=(1, 2)).astype(jnp.uint32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sc_mac(a_packed, w_packed, *, interpret: bool = True):
    """Accumulated XNOR-popcount MAC.

    a_packed, w_packed: uint32 (neurons, fan_in, words) with identical
    shapes; bits beyond the bitstream length must be zero in BOTH operands
    of no lane (the kernel XNORs raw words, so k must be a multiple of 32 —
    the system configuration uses k = 32).

    Returns uint32 (neurons,): sum of '1's of all product streams — the
    APC-accumulated MAC count.
    """
    n, fan_in, words = a_packed.shape
    assert w_packed.shape == a_packed.shape
    bn = BLOCK_NEURONS if n % BLOCK_NEURONS == 0 else 1
    grid = (n // bn,)
    return pl.pallas_call(
        _sc_mac_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, fan_in, words), lambda i: (i, 0, 0)),
            pl.BlockSpec((bn, fan_in, words), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.uint32),
        interpret=interpret,
    )(a_packed, w_packed)
