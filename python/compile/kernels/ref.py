"""Pure-jnp/numpy oracles for the Pallas kernels and the SC math model.

These mirror (bit-for-bit / closed-form) the Rust implementations in
``rust/src/sc/``:

* ``quantize_bipolar`` / ``dequantize_bipolar``  <-> ``sc::quantize_bipolar``
* ``pcc_bit``                                    <-> ``sc::pcc::pcc_bit``
* ``neuron_expectation``                         <-> ``sc::neuron::expectation*``
* ``sc_mac_ref``                                 <-> packed XNOR+popcount MAC

pytest asserts every Pallas kernel against these references across shapes
and dtypes (hypothesis sweeps), and the Rust integration tests replay the
same conventions.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def erf(x):
    """Abramowitz & Stegun 7.1.26 erf approximation (|err| < 1.5e-7).

    Used instead of jax.scipy.special.erf so the lowered HLO contains no
    `erf` opcode (xla_extension 0.5.1's text parser predates it), and so
    the math matches rust/src/sc/neuron.rs::erf bit-for-bit in structure.
    """
    sign = jnp.sign(x)
    ax = jnp.abs(x)
    t = 1.0 / (1.0 + 0.3275911 * ax)
    y = 1.0 - (
        ((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
        + 0.254829592
    ) * t * jnp.exp(-ax * ax)
    return sign * y


# ---------------------------------------------------------------------------
# Quantization (bipolar encoding, mirrors rust/src/sc/mod.rs)
# ---------------------------------------------------------------------------

def quantize_bipolar(v, bits: int):
    """[-1,1] value -> bipolar code in [0, 2^bits). floor(x+0.5) equals
    Rust's round-half-away-from-zero for the non-negative argument here."""
    levels = float(1 << bits)
    p = (jnp.clip(v, -1.0, 1.0) + 1.0) / 2.0
    q = jnp.floor(p * levels + 0.5)
    return jnp.minimum(q, levels - 1.0)


def dequantize_bipolar(code, bits: int):
    """Bipolar code -> value in [-1, 1)."""
    return code / float(1 << bits) * 2.0 - 1.0


def quantize_value(v, bits: int):
    """Quantize-dequantize roundtrip (the value the hardware represents)."""
    return dequantize_bipolar(quantize_bipolar(v, bits), bits)


# ---------------------------------------------------------------------------
# Neuron expectation (mirrors rust/src/sc/neuron.rs)
# ---------------------------------------------------------------------------

def m_bits(n: int) -> int:
    """ceil(log2(n+1)): comparator width covering counts 0..n."""
    return int(n).bit_length()


def neuron_expectation(pre, n: int, relu: bool, var=None):
    """Expected bipolar output of the Frasser SC neuron.

    ``pre`` = sum of product values; ``var`` = per-cycle variance of 2c
    (sum of 1-(a_j w_j)^2). With ``relu`` the SC-smoothed (correlated-OR)
    ReLU applies: E[max(2c, n)] = n + sigma*(phi(z) + z*Phi(z)), z=pre/sigma.
    """
    scale = float(1 << m_bits(n))
    if not relu:
        return (pre + n) / scale - 1.0
    sigma = jnp.sqrt(jnp.maximum(var, 1e-12))
    z = pre / sigma
    pdf = jnp.exp(-0.5 * z * z) / np.sqrt(2.0 * np.pi)
    cdf = 0.5 * (1.0 + erf(z / np.sqrt(2.0)))
    softplus = sigma * (pdf + z * cdf)
    return (softplus + n) / scale - 1.0


# ---------------------------------------------------------------------------
# PCC bit functions (mirror rust/src/sc/pcc.rs, LSB-first chains)
# ---------------------------------------------------------------------------

def nandnor_stage_inverted(n: int, i: int) -> bool:
    """Lemma 1 inverter-insertion rule (1-indexed stage i of n stages)."""
    return (i % 2 == 0) if n % 2 == 0 else (i % 2 == 1)


def pcc_bit(kind: str, x: np.ndarray, r: np.ndarray, bits: int) -> np.ndarray:
    """Vectorized PCC output bit. kind in {'cmp', 'mux', 'nandnor'}."""
    x = np.asarray(x, dtype=np.uint32)
    r = np.asarray(r, dtype=np.uint32)
    if kind == "cmp":
        return x > r
    if kind == "mux":
        o = np.zeros(np.broadcast(x, r).shape, dtype=bool)
        for i in range(bits):
            xi = (x >> i) & 1 == 1
            ri = (r >> i) & 1 == 1
            o = np.where(ri, xi, o)
        return o
    if kind == "nandnor":
        o = np.zeros(np.broadcast(x, r).shape, dtype=bool)
        for i in range(1, bits + 1):
            xi = (x >> (i - 1)) & 1 == 1
            ri = (r >> (i - 1)) & 1 == 1
            prog = ~xi if nandnor_stage_inverted(bits, i) else xi
            o = np.where(prog, ~(o | ri), ~(o & ri))
        return o
    raise ValueError(f"unknown PCC kind {kind!r}")


def pcc_streams_packed(kind: str, codes: np.ndarray, rs: np.ndarray, bits: int) -> np.ndarray:
    """Packed streams: codes (n,), rs (k,) -> uint32 (n, k//32); bit t of a
    word is cycle (32*word + t). k must be a multiple of 32."""
    k = rs.shape[0]
    assert k % 32 == 0, "pack requires k % 32 == 0"
    bits_nk = pcc_bit(kind, codes[:, None], rs[None, :], bits)  # (n, k) bool
    b = bits_nk.reshape(codes.shape[0], k // 32, 32).astype(np.uint32)
    shifts = np.arange(32, dtype=np.uint32)
    return (b << shifts[None, None, :]).sum(axis=2, dtype=np.uint32)


# ---------------------------------------------------------------------------
# Packed XNOR + popcount MAC (the APC-accumulated SC MAC)
# ---------------------------------------------------------------------------

def popcount32(x: np.ndarray) -> np.ndarray:
    """Population count of uint32 lanes (numpy reference)."""
    x = x.astype(np.uint64)
    c = np.zeros_like(x)
    for i in range(32):
        c += (x >> np.uint64(i)) & np.uint64(1)
    return c.astype(np.uint32)


def sc_mac_ref(a_packed: np.ndarray, w_packed: np.ndarray) -> np.ndarray:
    """Reference for the sc_mac Pallas kernel.

    a_packed, w_packed: uint32 (neurons, fan_in, words). Returns uint32
    (neurons,) = total '1' count of the XNOR products over all fan-in and
    cycles (= the APC-accumulated MAC sum feeding S2B).
    """
    prod = ~(a_packed ^ w_packed) & np.uint32(0xFFFFFFFF)
    return popcount32(prod).sum(axis=(1, 2)).astype(np.uint32)


def sc_mac_value(counts: np.ndarray, fan_in: int, k: int) -> np.ndarray:
    """Pre-activation sum represented by an accumulated MAC count:
    E[product ones per cycle] = counts/k = (pre + fan_in)/2."""
    return 2.0 * counts / k - fan_in
