"""Build-time training (the paper trains in PyTorch with SC math models
inserted; we do the same in JAX — section V-B). Never imported at runtime.

Minimal Adam implementation (no optax in this environment), cross-entropy
over the SC-mode forward so the weights adapt to the SC affine scaling and
the smoothed ReLU the hardware implements.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from . import model


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


@functools.partial(
    jax.jit, static_argnames=("spec_name", "mode", "bits", "lr", "noise_k", "noise_scale")
)
def train_step(params, opt_state, x, y, spec_name, mode="sc", bits=8, lr=1e-3,
               noise_key=None, noise_k=32, noise_scale=1.0):
    def loss_fn(p):
        logits = model.predict(
            p, x, spec_name, mode=mode, bits=bits,
            noise_key=noise_key, noise_k=noise_k, noise_scale=noise_scale,
        )
        return cross_entropy(logits, y)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    m, v, t = opt_state
    t = t + 1
    b1, b2, eps = 0.9, 0.999, 1e-8
    m = jax.tree_util.tree_map(lambda mi, g: b1 * mi + (1 - b1) * g, m, grads)
    v = jax.tree_util.tree_map(lambda vi, g: b2 * vi + (1 - b2) * g * g, v, grads)
    new_params = jax.tree_util.tree_map(
        lambda p, mi, vi: p
        - lr * (mi / (1 - b1**t)) / (jnp.sqrt(vi / (1 - b2**t)) + eps),
        params,
        m,
        v,
    )
    return new_params, (m, v, t), loss


def accuracy(params, x, y, spec_name, mode="sc", bits=8, batch=256) -> float:
    correct = 0
    for i in range(0, x.shape[0], batch):
        logits = model.predict(params, x[i : i + batch], spec_name, mode=mode, bits=bits)
        correct += int((jnp.argmax(logits, axis=1) == y[i : i + batch]).sum())
    return correct / x.shape[0]


def train(
    spec_name: str,
    dataset: str,
    n_train: int = 6000,
    n_test: int = 1000,
    epochs: int = 3,
    batch: int = 64,
    lr: float = 2e-3,
    bits: int = 8,
    mode: str = "sc",
    seed: int = 0,
    verbose: bool = True,
    noise_ramp: bool = False,
):
    """Train and return (params, test_images, test_labels, test_accuracy)."""
    xtr, ytr = data_mod.dataset(dataset, n_train, seed=seed)
    xte, yte = data_mod.dataset(dataset, n_test, seed=seed + 10_000)
    spec = model.spec_by_name(spec_name)
    params = model.init_params(spec, seed=seed)
    params = model.calibrate(params, jnp.asarray(xtr[:128]), spec, mode=mode, bits=bits)
    opt = (
        jax.tree_util.tree_map(jnp.zeros_like, params),
        jax.tree_util.tree_map(jnp.zeros_like, params),
        0,
    )
    rng = np.random.default_rng(seed + 1)
    xtr_j, ytr_j = jnp.asarray(xtr), jnp.asarray(ytr)
    key = jax.random.PRNGKey(seed + 99)
    for epoch in range(epochs):
        order = rng.permutation(n_train)
        losses = []
        # Optional noise annealing (experimental): bootstrap noiselessly,
        # then ramp toward full SC sampling noise so the weights learn to
        # clear the k-cycle noise floor. Off by default: the logits-domain
        # noise needs a noise-aware loss to converge (see EXPERIMENTS.md).
        ramp = (
            0.0
            if (not noise_ramp or epochs == 1)
            else min(1.0, epoch / max(1, epochs - 2))
        )
        for i in range(0, n_train - batch + 1, batch):
            idx = order[i : i + batch]
            nk = None
            if mode == "sc" and ramp > 0.0:
                key, nk = jax.random.split(key)
            params, opt, loss = train_step(
                params, opt, xtr_j[idx], ytr_j[idx], spec_name, mode=mode, bits=bits,
                lr=lr, noise_key=nk, noise_scale=ramp,
            )
            losses.append(float(loss))
        if verbose:
            acc = accuracy(params, jnp.asarray(xte), jnp.asarray(yte), spec_name, mode=mode, bits=bits)
            print(f"[{spec_name}/{dataset}] epoch {epoch}: loss {np.mean(losses):.4f} test acc {acc:.4f}")
    final = accuracy(params, jnp.asarray(xte), jnp.asarray(yte), spec_name, mode=mode, bits=bits)
    return params, xte, yte, final
