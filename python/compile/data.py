"""Synthetic datasets (build-time only).

This environment has no network access and no MNIST/CIFAR-10 archives, so
we substitute procedurally generated datasets with the same shapes and the
same role in the experiments (DESIGN.md §Substitutions):

* ``digits``  — 28x28x1 MNIST-like: a 7x5 bitmap digit font rendered with
  random shift, scale jitter, stroke noise and background noise.
* ``textures`` — 32x32x3 CIFAR-like: ten parametric texture/shape classes
  (stripes at several orientations/frequencies, checkerboards, rings,
  gradients, blobs) with color and noise jitter.

Everything is deterministic given the seed. Accuracy *shapes* (vs bitstream
length / precision) transfer; absolute accuracies are reported for these
sets and flagged as synthetic in EXPERIMENTS.md.
"""

from __future__ import annotations

import numpy as np

# 7x5 digit bitmaps (classic LED/LCD-style font).
_DIGIT_FONT = {
    0: ["11111", "10001", "10001", "10001", "10001", "10001", "11111"],
    1: ["00100", "01100", "00100", "00100", "00100", "00100", "01110"],
    2: ["11111", "00001", "00001", "11111", "10000", "10000", "11111"],
    3: ["11111", "00001", "00001", "01111", "00001", "00001", "11111"],
    4: ["10001", "10001", "10001", "11111", "00001", "00001", "00001"],
    5: ["11111", "10000", "10000", "11111", "00001", "00001", "11111"],
    6: ["11111", "10000", "10000", "11111", "10001", "10001", "11111"],
    7: ["11111", "00001", "00010", "00100", "01000", "01000", "01000"],
    8: ["11111", "10001", "10001", "11111", "10001", "10001", "11111"],
    9: ["11111", "10001", "10001", "11111", "00001", "00001", "11111"],
}


def _render_digit(digit: int, rng: np.random.Generator) -> np.ndarray:
    """Render one 28x28 grayscale digit in [0, 1]."""
    img = np.zeros((28, 28), dtype=np.float32)
    bitmap = _DIGIT_FONT[digit]
    # Scale jitter: cell size 3 or 4 px per font pixel.
    cell = rng.integers(3, 5)
    h, w = 7 * cell, 5 * cell
    oy = rng.integers(1, 28 - h) if 28 - h > 1 else 0
    ox = rng.integers(1, 28 - w) if 28 - w > 1 else 0
    intensity = rng.uniform(0.75, 1.0)
    for r, row in enumerate(bitmap):
        for c, ch in enumerate(row):
            if ch == "1":
                img[oy + r * cell : oy + (r + 1) * cell, ox + c * cell : ox + (c + 1) * cell] = (
                    intensity
                )
    # Stroke dropout + speckle.
    img *= rng.uniform(0.82, 1.0, size=img.shape).astype(np.float32)
    img += rng.normal(0.0, 0.06, size=img.shape).astype(np.float32)
    # Light blur (3x3 box) softens the hard font edges.
    k = np.ones((3, 3), dtype=np.float32) / 9.0
    padded = np.pad(img, 1, mode="edge")
    blurred = sum(
        padded[dy : dy + 28, dx : dx + 28] * k[dy, dx] for dy in range(3) for dx in range(3)
    )
    return np.clip(blurred, 0.0, 1.0)


def make_digits(n: int, seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """n MNIST-like samples: images (n, 1, 28, 28) in [0,1], labels (n,)."""
    rng = np.random.default_rng(seed)
    images = np.zeros((n, 1, 28, 28), dtype=np.float32)
    labels = rng.integers(0, 10, size=n).astype(np.int32)
    for i in range(n):
        images[i, 0] = _render_digit(int(labels[i]), rng)
    return images, labels


def _texture(cls: int, rng: np.random.Generator) -> np.ndarray:
    """Render one 3x32x32 RGB texture in [0, 1] for class ``cls``."""
    yy, xx = np.mgrid[0:32, 0:32].astype(np.float32)
    phase = rng.uniform(0, 2 * np.pi)
    freq = rng.uniform(0.8, 1.2)
    if cls == 0:  # horizontal stripes
        base = np.sin(yy * 0.7 * freq + phase)
    elif cls == 1:  # vertical stripes
        base = np.sin(xx * 0.7 * freq + phase)
    elif cls == 2:  # diagonal stripes
        base = np.sin((xx + yy) * 0.5 * freq + phase)
    elif cls == 3:  # checkerboard
        base = np.sign(np.sin(xx * 0.9 * freq + phase) * np.sin(yy * 0.9 * freq + phase))
    elif cls == 4:  # rings
        cy, cx = rng.uniform(12, 20), rng.uniform(12, 20)
        r = np.sqrt((yy - cy) ** 2 + (xx - cx) ** 2)
        base = np.sin(r * 0.9 * freq + phase)
    elif cls == 5:  # radial gradient
        cy, cx = rng.uniform(10, 22), rng.uniform(10, 22)
        r = np.sqrt((yy - cy) ** 2 + (xx - cx) ** 2)
        base = 1.0 - r / r.max() * 2.0
    elif cls == 6:  # horizontal gradient
        base = xx / 16.0 - 1.0
    elif cls == 7:  # blob (gaussian bump)
        cy, cx = rng.uniform(10, 22), rng.uniform(10, 22)
        s = rng.uniform(4, 7)
        base = 2.0 * np.exp(-((yy - cy) ** 2 + (xx - cx) ** 2) / (2 * s * s)) - 1.0
    elif cls == 8:  # crosshatch
        base = 0.5 * (np.sin(xx * 1.1 * freq) + np.sin(yy * 1.1 * freq))
    else:  # 9: high-frequency noise field with structure
        base = np.sin(xx * 2.2 * freq + phase) * np.sin(yy * 0.3 * freq)
    base = base.astype(np.float32)
    # Color modulation per channel + noise.
    img = np.zeros((3, 32, 32), dtype=np.float32)
    for ch in range(3):
        gain = rng.uniform(0.35, 0.65)
        off = rng.uniform(0.3, 0.7)
        img[ch] = np.clip(off + gain * base + rng.normal(0, 0.07, base.shape), 0, 1)
    return img


def make_textures(n: int, seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """n CIFAR-like samples: images (n, 3, 32, 32) in [0,1], labels (n,)."""
    rng = np.random.default_rng(seed)
    images = np.zeros((n, 3, 32, 32), dtype=np.float32)
    labels = rng.integers(0, 10, size=n).astype(np.int32)
    for i in range(n):
        images[i] = _texture(int(labels[i]), rng)
    return images, labels


def dataset(name: str, n: int, seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Dispatch by dataset name ('digits' or 'textures')."""
    if name == "digits":
        return make_digits(n, seed)
    if name == "textures":
        return make_textures(n, seed)
    raise ValueError(f"unknown dataset {name!r}")
