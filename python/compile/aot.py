"""AOT entry point: python -m compile.aot --out ../artifacts

Runs ONCE at build time (`make artifacts`); Python never touches the
request path. Produces:

* ``lenet5_b{1,32}.hlo.txt``   — the SC-equivalent quantized LeNet-5
  inference graph (Pallas MAC kernels inside), lowered to HLO **text** —
  not ``.serialize()``: jax>=0.5 emits 64-bit instruction ids that
  xla_extension 0.5.1 rejects; the text parser reassigns ids (see
  /opt/xla-example/README.md).
* ``sc_mac_demo.hlo.txt``      — the packed XNOR+popcount L1 kernel as a
  standalone graph (128 neurons x fan-in 25 x 1 word), for the Rust
  bit-exact cross-check.
* ``{lenet5,cifar_net}_{sc,fixed}.weights.bin`` — trained weights + the
  per-layer re-encoder affines (format below).
* ``digits_test.bin``, ``textures_test.bin``    — synthetic test sets.
* ``manifest.txt``             — key=value metadata incl. train accuracy.

Binary formats (little-endian):
  weights: b"SCNNW1\\0\\0" u32 n_layers { u32 rows u32 cols f32 g f32 mu
           f32[rows*cols] row-major } — conv flattened (oc, ic*k*k).
  dataset: b"SCNND1\\0\\0" u32 n u32 c u32 h u32 w u8[n*c*h*w] u8[n]
"""

from __future__ import annotations

import argparse
import struct
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model, train
from .kernels import sc_mac


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe interchange).

    ``print_large_constants=True`` is load-bearing: the default printer
    elides big literals as ``{...}``, which xla_extension 0.5.1's parser
    silently accepts as ZEROS — the compiled model then returns constants
    (all logits equal). Cost: the text carries the full trained weights.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def write_weights(path: Path, params, spec) -> None:
    with open(path, "wb") as f:
        f.write(b"SCNNW1\0\0")
        f.write(struct.pack("<I", len(params)))
        for layer, p in zip(spec["layers"], params):
            w = np.asarray(jnp.clip(p["w"], -1.0, 1.0), dtype=np.float32)
            if layer["kind"] == "conv":
                w = w.reshape(w.shape[0], -1)  # (oc, ic*k*k) — conv_gather order
            f.write(struct.pack("<II", w.shape[0], w.shape[1]))
            f.write(struct.pack("<ff", float(p["g"]), float(p["mu"])))
            f.write(w.astype("<f4").tobytes())


def write_dataset(path: Path, images: np.ndarray, labels: np.ndarray) -> None:
    n, c, h, w = images.shape
    with open(path, "wb") as f:
        f.write(b"SCNND1\0\0")
        f.write(struct.pack("<IIII", n, c, h, w))
        f.write((np.clip(images, 0, 1) * 255.0 + 0.5).astype(np.uint8).tobytes())
        f.write(labels.astype(np.uint8).tobytes())


def export_model_hlo(out: Path, params, name: str, batches=(1, 8, 32)) -> None:
    """Serving graphs (XLA-native lowering) + one Pallas-lowered variant.

    Perf note (EXPERIMENTS.md §Perf / L2): interpret-mode pallas_call lowers
    to while-loops that the CPU PJRT runtime executes ~85x slower than the
    equivalent fused XLA ops (878 ms vs 10.3 ms for a 32-batch LeNet-5), so
    the *serving* artifacts take the XLA-native path; the Pallas lowering is
    exported separately to prove the full three-layer composition and feed
    the kernel-level cross-checks. On a real TPU the Mosaic path replaces
    interpret mode and this trade-off disappears.
    """
    for b in batches:
        spec_in = jax.ShapeDtypeStruct(
            (b,) + model.spec_by_name(name)["input"], jnp.float32
        )

        def infer(x):
            return (model.predict(params, x, name, mode="sc", bits=8, use_pallas=False),)

        lowered = jax.jit(infer).lower(spec_in)
        text = to_hlo_text(lowered)
        path = out / f"{name}_b{b}.hlo.txt"
        path.write_text(text)
        print(f"wrote {path} ({len(text)} chars)")

    def infer_pallas(x):
        return (model.predict(params, x, name, mode="sc", bits=8, use_pallas=True),)

    lowered = jax.jit(infer_pallas).lower(
        jax.ShapeDtypeStruct((1,) + model.spec_by_name(name)["input"], jnp.float32)
    )
    path = out / f"{name}_pallas_b1.hlo.txt"
    path.write_text(to_hlo_text(lowered))
    print(f"wrote {path}")


def export_sc_mac_demo(out: Path) -> None:
    a_spec = jax.ShapeDtypeStruct((128, 25, 1), jnp.uint32)

    def demo(a, w):
        return (sc_mac.sc_mac(a, w),)

    lowered = jax.jit(demo).lower(a_spec, a_spec)
    path = out / "sc_mac_demo.hlo.txt"
    path.write_text(to_hlo_text(lowered))
    print(f"wrote {path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--quick", action="store_true", help="tiny training run (CI smoke)")
    args = ap.parse_args()
    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)

    manifest = {}
    jobs = [
        ("lenet5", "digits", dict(n_train=6000, n_test=1000, epochs=4)),
        ("cifar_net", "textures", dict(n_train=4000, n_test=500, epochs=3)),
    ]
    if args.quick:
        jobs = [("lenet5", "digits", dict(n_train=800, n_test=200, epochs=1))]

    lenet_params = None
    for spec_name, dataset, kw in jobs:
        for mode in ("sc", "fixed"):
            params, xte, yte, acc = train.train(
                spec_name, dataset, mode=mode, **kw
            )
            spec = model.spec_by_name(spec_name)
            write_weights(out / f"{spec_name}_{mode}.weights.bin", params, spec)
            manifest[f"acc_{spec_name}_{mode}"] = f"{acc:.4f}"
            if mode == "sc":
                write_dataset(out / f"{dataset}_test.bin", xte, yte)
                if spec_name == "lenet5":
                    lenet_params = params

    if lenet_params is not None:
        export_model_hlo(out, lenet_params, "lenet5", batches=(1, 8, 32))
    export_sc_mac_demo(out)

    manifest["bits"] = "8"
    manifest["bitstream_len"] = "32"
    with open(out / "manifest.txt", "w") as f:
        for k, v in sorted(manifest.items()):
            f.write(f"{k}={v}\n")
    print("manifest:", manifest)


if __name__ == "__main__":
    main()
