//! END-TO-END driver: proves all three layers compose on a real workload.
//!
//! 1. loads the AOT artifacts (`make artifacts`): the trained LeNet-5
//!    SC-equivalent inference graphs (L2, lowered once from JAX), the
//!    Pallas sc_mac kernel graph (L1), trained weights and the synthetic
//!    test set;
//! 2. serves the full test set through the L3 coordinator (router +
//!    dynamic batcher + PJRT workers) and reports accuracy / latency /
//!    throughput;
//! 3. cross-checks served predictions against the bit-exact stochastic
//!    simulation (LFSR→PCC→XNOR→APC→B2S→ReLU/MP→S2B) and the expectation
//!    model on a sample of images;
//! 4. executes the L1 Pallas kernel artifact via PJRT and verifies it
//!    bit-for-bit against the Rust packed-bitstream engine.
//!
//! Results are recorded in EXPERIMENTS.md. Run:
//! `make artifacts && cargo run --release --example mnist_e2e`

use anyhow::{bail, Context, Result};
use scnn::accel::network::{classify, forward, ForwardMode};
use scnn::accel::layers::NetworkSpec;
use scnn::coordinator::{Coordinator, CoordinatorConfig};
use scnn::data::{load_manifest, Artifacts, Dataset, ModelWeights};
use scnn::runtime::Engine;
use scnn::sc::bitstream::Bitstream;
use std::time::{Duration, Instant};

fn main() -> Result<()> {
    let artifacts = Artifacts::default_dir();
    if !artifacts.present() {
        bail!("artifacts missing — run `make artifacts` first");
    }
    let manifest = load_manifest(&artifacts.manifest())?;
    println!("manifest: {manifest:?}\n");

    // ---- 2. serve the full test set through the coordinator ----
    let ds = Dataset::load(&artifacts.dataset("digits"))?;
    let cfg = CoordinatorConfig {
        hlo_ladder: vec![
            (1, artifacts.hlo("lenet5", 1)),
            (8, artifacts.hlo("lenet5", 8)),
            (32, artifacts.hlo("lenet5", 32)),
        ],
        image_len: ds.shape.0 * ds.shape.1 * ds.shape.2,
        image_dims: ds.shape,
        classes: 10,
        linger: Duration::from_millis(2),
    };
    let coord = Coordinator::start(cfg).context("starting coordinator")?;
    let t = Instant::now();
    let preds = coord.infer_all(&ds.images, 32)?;
    let wall = t.elapsed();
    let correct = preds
        .iter()
        .zip(&ds.labels)
        .filter(|(&p, &l)| p == l as usize)
        .count();
    let st = coord.stats();
    println!("== serving (L3 coordinator + L2 PJRT graph) ==");
    println!(
        "  {} images in {:.1} ms  ->  {:.0} img/s",
        ds.len(),
        wall.as_secs_f64() * 1e3,
        ds.len() as f64 / wall.as_secs_f64()
    );
    println!(
        "  accuracy {:.2}%  (python-side training accuracy: {})",
        100.0 * correct as f64 / ds.len() as f64,
        manifest.get("acc_lenet5_sc").map(String::as_str).unwrap_or("?")
    );
    println!(
        "  latency p50 {} µs  p99 {} µs  mean batch {:.1}",
        st.latency_percentile_us(50.0),
        st.latency_percentile_us(99.0),
        st.mean_batch()
    );

    // ---- 3. bit-exact SC cross-check ----
    let net = NetworkSpec::lenet5();
    let weights = ModelWeights::load(&artifacts.weights("lenet5", "sc"))?.quantize(8);
    let n_check = 40.min(ds.len());
    let mut agree_exp = 0;
    let mut agree_sc = 0;
    let mut agree_noisy = 0;
    let t = Instant::now();
    for i in 0..n_check {
        let img: Vec<f64> = ds.images[i].iter().map(|&v| v as f64).collect();
        let p_exp = classify(&forward(&net, &weights, &img, ForwardMode::Expectation));
        let p_sc = classify(&forward(
            &net,
            &weights,
            &img,
            ForwardMode::Stochastic { k: 32, seed: 1 + i as u32 },
        ));
        let p_noisy = classify(&forward(
            &net,
            &weights,
            &img,
            ForwardMode::NoisyExpectation { k: 4096, seed: 1 + i as u32 },
        ));
        agree_exp += (p_exp == preds[i]) as usize;
        agree_sc += (p_sc == ds.labels[i] as usize) as usize;
        agree_noisy += (p_noisy == ds.labels[i] as usize) as usize;
    }
    println!("\n== bit-exact stochastic datapath (8-bit) ==");
    println!(
        "  expectation model vs served graph: {agree_exp}/{n_check} agree ({:.0}%)",
        100.0 * agree_exp as f64 / n_check as f64
    );
    println!(
        "  SC-noise model accuracy at k=4096: {agree_noisy}/{n_check} ({:.0}%)",
        100.0 * agree_noisy as f64 / n_check as f64
    );
    println!(
        "  full LFSR→PCC→XNOR→APC→B2S→S2B sim at k=32: {agree_sc}/{n_check} ({:.0}%), {:.2} s",
        100.0 * agree_sc as f64 / n_check as f64,
        t.elapsed().as_secs_f64()
    );
    println!(
        "  (k=32 sits below this network's SC noise floor — the training\n            is not yet noise-aware; see EXPERIMENTS.md Fig. 11 notes.)"
    );
    if agree_exp * 10 < n_check * 9 {
        bail!("expectation model diverged from the served graph");
    }
    if agree_noisy * 10 < n_check * 8 {
        bail!("SC-noise model should classify well at k=4096");
    }

    // ---- 4. L1 Pallas kernel vs the Rust bitstream engine ----
    let kernel = Engine::load(&artifacts.dir.join("sc_mac_demo.hlo.txt"))?;
    let (neurons, fan_in, words) = (128usize, 25usize, 1usize);
    let mut rng: u64 = 0x5EED;
    let mut step = move || {
        rng ^= rng << 13;
        rng ^= rng >> 7;
        rng ^= rng << 17;
        rng as u32
    };
    let a: Vec<u32> = (0..neurons * fan_in * words).map(|_| step()).collect();
    let w: Vec<u32> = (0..neurons * fan_in * words).map(|_| step()).collect();
    let counts = kernel.run_u32_pair(&a, &w, &[neurons as i64, fan_in as i64, words as i64])?;
    let mut mismatches = 0;
    for n in 0..neurons {
        let mut expected = 0u32;
        for j in 0..fan_in {
            let idx = n * fan_in + j;
            let sa = Bitstream::from_fn(32, |t| (a[idx] >> t) & 1 == 1);
            let sw = Bitstream::from_fn(32, |t| (w[idx] >> t) & 1 == 1);
            expected += sa.xnor(&sw).count_ones();
        }
        if counts[n] != expected {
            mismatches += 1;
        }
    }
    println!("\n== L1 Pallas sc_mac kernel (PJRT) vs Rust bitstream engine ==");
    println!("  {neurons} neurons × {fan_in} products × 32 cycles: {mismatches} mismatches");
    if mismatches > 0 {
        bail!("kernel/engine mismatch");
    }
    println!("\nE2E OK: all three layers compose.");
    Ok(())
}
